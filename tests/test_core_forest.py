"""Property + unit tests for the radix-tree-forest core (paper Secs. 2-3)."""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    build_cdf,
    build_forest,
    build_forest_apetrei,
    build_forest_from_cdf,
    depth_stats,
    forest_to_numpy,
    normalize_weights,
    np_build_cdf,
    np_sample_cutpoint_binary_counting,
    np_sample_forest_counting,
    sample_binary,
    sample_cutpoint_binary,
    sample_cutpoint_linear,
    sample_forest,
    sample_forest_with_stats,
    sample_linear,
    validate_forest,
)

settings = hypothesis.settings(max_examples=30, deadline=None)


def _same_interval(cdf, a, b):
    """Equal index, or zero-width-tied intervals (same boundary value)."""
    return np.array_equal(a, b) or bool(np.all(cdf[a] == cdf[b]))


weights_strategy = st.lists(
    st.floats(min_value=0.0, max_value=1e6, allow_nan=False, width=32),
    min_size=1,
    max_size=300,
).filter(lambda w: sum(w) > 1e-6)


@settings
@hypothesis.given(
    w=weights_strategy,
    m=st.integers(min_value=1, max_value=512),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_forest_inverts_cdf(w, m, seed):
    """Core property: forest traversal == monotone inverse CDF, for any
    non-negative weights (including zeros) and any guide-table size."""
    f = build_forest(jnp.asarray(w, jnp.float32), m)
    xi = np.random.default_rng(seed).random(512).astype(np.float32)
    got = np.asarray(sample_forest(f, jnp.asarray(xi)))
    oracle = np.asarray(sample_binary(f.cdf, jnp.asarray(xi)))
    cdf = np.asarray(f.cdf)
    assert _same_interval(cdf, got, oracle)
    # Inversion property: P_{i-1} <= xi < P_i
    assert np.all(cdf[got] <= xi) and np.all(xi < cdf[got + 1])


@settings
@hypothesis.given(
    w=weights_strategy.filter(lambda w: all(x > 1e-6 for x in w)),
    m=st.integers(min_value=1, max_value=64),
)
def test_vectorized_builder_matches_apetrei(w, m):
    """The TPU-native builder is bit-identical to the faithful Algorithm-1
    emulation (same trees, same guide table) for positive weights."""
    f = build_forest(jnp.asarray(w, jnp.float32), m)
    ap = build_forest_apetrei(np.asarray(f.cdf), m)
    fn = forest_to_numpy(f)
    assert np.array_equal(fn["table"], ap["table"])
    assert np.array_equal(fn["left"], ap["left"])
    assert np.array_equal(fn["right"], ap["right"])


@settings
@hypothesis.given(
    w=weights_strategy,
    m=st.integers(min_value=1, max_value=128),
)
def test_forest_structure_valid(w, m):
    f = build_forest(jnp.asarray(w, jnp.float32), m)
    validate_forest(f)


@settings
@hypothesis.given(
    w=weights_strategy,
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_monotonicity(w, seed):
    """The paper's central claim vs the Alias Method: the mapping xi -> i is
    non-decreasing, so low-discrepancy structure survives the warp."""
    f = build_forest(jnp.asarray(w, jnp.float32), 32)
    xi = np.sort(np.random.default_rng(seed).random(256).astype(np.float32))
    got = np.asarray(sample_forest(f, jnp.asarray(xi)))
    assert np.all(np.diff(got) >= 0)


@settings
@hypothesis.given(
    w=weights_strategy,
    m=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_all_samplers_agree(w, m, seed):
    cdf = build_cdf(jnp.asarray(w, jnp.float32))
    f = build_forest_from_cdf(cdf, m)
    xi = np.random.default_rng(seed).random(256).astype(np.float32)
    xj = jnp.asarray(xi)
    cdf_np = np.asarray(cdf)
    ref = np.asarray(sample_binary(cdf, xj))
    n = len(w)
    for name, got in {
        "linear": np.asarray(sample_linear(cdf, xj)),
        "cut_bin": np.asarray(sample_cutpoint_binary(cdf, f.cell_first, xj)),
        "cut_lin": np.asarray(sample_cutpoint_linear(cdf, f.cell_first, xj, n)),
        "forest": np.asarray(sample_forest(f, xj)),
        "forest_nofb": np.asarray(sample_forest(f, xj, use_fallback=False)),
    }.items():
        assert _same_interval(cdf_np, got, ref), name


def test_distribution_preserved_chi2():
    """Sampled histogram matches p (chi^2 well under a generous bound)."""
    rng = np.random.default_rng(7)
    p = normalize_weights(rng.random(64) ** 4 + 1e-4)
    f = build_forest(jnp.asarray(p), 64)
    n_samples = 1 << 16
    xi = rng.random(n_samples).astype(np.float32)
    idx = np.asarray(sample_forest(f, jnp.asarray(xi)))
    counts = np.bincount(idx, minlength=64)
    expected = p * n_samples
    chi2 = float(np.sum((counts - expected) ** 2 / np.maximum(expected, 1e-9)))
    # 63 dof: mean 63, sd ~11; 200 is a ~12-sigma guard against regression
    assert chi2 < 200, chi2


def test_counting_twins_match_jax():
    rng = np.random.default_rng(3)
    w = normalize_weights(rng.random(200) ** 6 + 1e-9)
    f = build_forest(jnp.asarray(w), 128)
    xi = rng.random(2048).astype(np.float32)
    i_jax, visits = sample_forest_with_stats(f, jnp.asarray(xi))
    i_np, loads = np_sample_forest_counting(f, xi)
    assert np.array_equal(np.asarray(i_jax), i_np)
    # numpy twin counts the guide load too
    assert np.array_equal(np.asarray(visits) + 1, loads)


def test_degenerate_ties_fall_back():
    """Zero-width intervals chain deeper than the 32-level radix bound; the
    build must flag those cells and fallback traversal must stay correct."""
    w = np.zeros(300, np.float32)
    w[150] = 1.0
    f = build_forest(jnp.asarray(w + 1e-12), 16)
    assert int(np.asarray(f.fallback).sum()) >= 1
    xi = np.random.default_rng(0).random(1024).astype(np.float32)
    got = np.asarray(sample_forest(f, jnp.asarray(xi)))
    cdf = np.asarray(f.cdf)
    assert np.all(cdf[got] <= xi) and np.all(xi < cdf[got + 1])


def test_single_interval():
    f = build_forest(jnp.asarray([3.0], jnp.float32), 8)
    xi = jnp.asarray([0.0, 0.3, 0.999], jnp.float32)
    assert np.array_equal(np.asarray(sample_forest(f, xi)), [0, 0, 0])


def test_table1_shape_of_results():
    """Sanity on the Table-1 reproduction: forest beats binary search on
    avg_32 for the high-dynamic-range periodic distributions."""
    n = 256
    rng = np.random.default_rng(0)
    xi = rng.random(1 << 14).astype(np.float32)
    w = normalize_weights((np.arange(n) % 64 + 1.0) ** 35)
    f = build_forest(jnp.asarray(w), 256)
    _, loads_f = np_sample_forest_counting(f, xi)
    _, loads_b = np_sample_cutpoint_binary_counting(
        np.asarray(f.cdf), np.asarray(f.cell_first), np.asarray(f.table), xi
    )
    from repro.core import warp_cost

    assert warp_cost(loads_f) < warp_cost(loads_b)


_FUZZ_KINDS = ("uniform", "powerlaw", "ties", "zeros", "wide", "single")


def _fuzz_weights(kind: str, n: int, rng) -> np.ndarray:
    if kind == "uniform":
        return rng.random(n).astype(np.float32) + np.float32(1e-3)
    if kind == "powerlaw":
        return (rng.random(n).astype(np.float32) ** 8) + np.float32(1e-9)
    if kind == "ties":   # many exact float32 ties -> zero separator distances
        base = rng.random(max(n // 8, 1)).astype(np.float32) + np.float32(1e-3)
        return base[rng.integers(0, len(base), n)]
    if kind == "zeros":  # ~half the intervals have zero width
        w = rng.random(n).astype(np.float32)
        w[rng.random(n) < 0.5] = 0.0
        w[rng.integers(0, n)] = 1.0   # keep the total positive
        return w
    if kind == "wide":   # 60 decades of dynamic range in one vector
        return (10.0 ** rng.uniform(-30, 30, n)).astype(np.float32)
    return rng.random(1).astype(np.float32) + np.float32(0.5)   # single


@pytest.mark.parametrize("m", [1, 7, 64, 1024])
@pytest.mark.parametrize("kind", _FUZZ_KINDS)
def test_fuzz_matrix_builder_bit_identical_and_valid(kind, m):
    """Randomized regression matrix beyond the fixed cases above: every
    weight family (power-law, uniform, exact ties, zeros, single-element,
    1e-30..1e30 spans) x guide-table size must (a) produce a structurally
    valid forest, (b) be bit-identical to the Algorithm-1 emulation, and
    (c) satisfy the inversion property under traversal."""
    rng = np.random.default_rng(1000 * m + _FUZZ_KINDS.index(kind))
    for n in (1,) if kind == "single" else (2, 13, 300):
        w = _fuzz_weights(kind, n, rng)
        f = build_forest(jnp.asarray(w), m)
        validate_forest(f)
        ap = build_forest_apetrei(np.asarray(f.cdf), m)
        fn = forest_to_numpy(f)
        for key in ("table", "left", "right"):
            assert np.array_equal(fn[key], ap[key]), (kind, n, m, key)
        xi = rng.random(256).astype(np.float32)
        got = np.asarray(sample_forest(f, jnp.asarray(xi)))
        cdf = np.asarray(f.cdf)
        assert np.all(cdf[got] <= xi) and np.all(xi < cdf[got + 1]), (kind, n, m)


def test_np_build_cdf_matches_jax():
    rng = np.random.default_rng(11)
    w = rng.random(100).astype(np.float32)
    np.testing.assert_allclose(
        np_build_cdf(w), np.asarray(build_cdf(jnp.asarray(w))), atol=2e-7
    )


def test_batch_cost_is_lane_max():
    """DESIGN §3: predicated batch traversal costs max-per-batch visits —
    the while_loop iteration count equals the deepest lane's node count
    (the hardware analogue of the paper's average_32)."""
    rng = np.random.default_rng(0)
    w = normalize_weights(rng.random(256) ** 15 + 1e-12)
    f = build_forest(jnp.asarray(w), 64)
    xi = jnp.asarray(rng.random(1024), jnp.float32)
    _, visits = sample_forest_with_stats(f, xi)
    v = np.asarray(visits)
    # per-32-lane groups: cost of the group = its max (all lanes step together)
    groups = v[: 1024 // 32 * 32].reshape(-1, 32)
    assert np.all(groups.max(axis=1) >= groups.mean(axis=1))
    assert v.max() <= 64  # bounded by MAX_DEPTH guard for real CDFs
