"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step + decode parity. Full configs are exercised only via the dry-run."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import decode_step, forward, init_params, loss_fn, prefill


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


def _batch(cfg, B, S, rng):
    batch = {}
    if cfg.frontend == "embed":
        batch["embeds"] = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", C.ARCHS)
def test_forward_and_train_step(arch):
    cfg = _f32(C.get_reduced(arch))
    rng = np.random.default_rng(hash(arch) % 2**31)
    B, S = 2, 16
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, B, S, rng)
    logits, _ = forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    # one SGD step must reduce nothing but must be finite + change params
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    assert np.isfinite(float(loss))
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g))), grads, 0.0
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", C.ARCHS)
def test_decode_matches_forward(arch):
    """prefill(S) + decode_step == forward(S+1) at the last position.

    capacity_factor is raised so no MoE token drops occur: GShard capacity
    dropping is token-count dependent by design, so exact decode parity only
    holds drop-free (standard behavior; drops are a training-time tradeoff).
    Encoder frames are a separate modality and stay identical in both runs.
    """
    cfg = dataclasses.replace(_f32(C.get_reduced(arch)), capacity_factor=8.0)
    rng = np.random.default_rng(0)
    B, S = 2, 12
    params = init_params(jax.random.PRNGKey(2), cfg)
    batch = _batch(cfg, B, S + 1, rng)
    if cfg.encoder_layers:
        batch["frames"] = batch["frames"][:, : S]

    full_logits, _ = forward(params, cfg, batch)
    want = np.asarray(full_logits[:, -1])

    pre = {k: (v[:, :S] if k in ("tokens", "embeds", "labels") else v)
           for k, v in batch.items()}
    _, cache, enc_out = prefill(params, cfg, pre, max_seq=S + 8)
    if cfg.frontend == "embed":
        tok = batch["embeds"][:, S : S + 1]
    else:
        tok = batch["tokens"][:, S]
    pos = jnp.full((B,), S, jnp.int32)
    got, _ = decode_step(params, cfg, cache, tok, pos, enc_out)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_param_counts_match_assignment():
    """Full-scale parameter totals land on the assigned model names."""
    expect = {
        "jamba_1_5_large_398b": (398e9, 0.05),
        "kimi_k2_1t_a32b": (1.04e12, 0.05),
        "qwen1_5_0_5b": (0.5e9, 0.3),
        "stablelm_3b": (2.8e9, 0.25),
        "qwen3_4b": (4e9, 0.15),
        "granite_3_8b": (8.2e9, 0.15),
        "whisper_small": (0.25e9, 0.4),
        "internvl2_76b": (70e9, 0.15),
        "xlstm_1_3b": (1.5e9, 0.4),
    }
    for arch, (want, tol) in expect.items():
        total, _ = C.get(arch).param_count()
        assert abs(total - want) / want < tol, (arch, total)


def test_active_params_match_a_labels():
    for arch, want in [("llama4_maverick_400b_a17b", 17e9), ("kimi_k2_1t_a32b", 32e9)]:
        _, active = C.get(arch).param_count()
        assert abs(active - want) / want < 0.15, (arch, active)


def test_long_context_eligibility():
    subq = {a for a in C.ARCHS if C.get(a).subquadratic}
    assert subq == {"jamba_1_5_large_398b", "xlstm_1_3b"}


def test_flash_attention_backend_matches_einsum():
    """cfg.attn_impl='flash' must reproduce the einsum path end-to-end."""
    cfg_e = dataclasses.replace(_f32(C.get_reduced("qwen3_4b")), n_layers=2)
    cfg_f = dataclasses.replace(cfg_e, attn_impl="flash")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), cfg_e)
    batch = _batch(cfg_e, 2, 64, rng)
    a, _ = forward(params, cfg_e, batch)
    b, _ = forward(params, cfg_f, batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
