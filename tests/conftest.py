"""Suite-wide fixtures/config.

Dependency gating: the property tests use Hypothesis, but the execution
image does not ship it and the repo rule forbids installing packages. When
the real package is importable we use it; otherwise ``tests/_stubs`` (a
deterministic API-compatible subset) is appended to ``sys.path`` so the
suite degrades to seeded fuzzing instead of dying at collection.
"""
import os
import sys

try:  # prefer the real package when the environment has it
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "_stubs")
    )
