"""Differential conformance suite for ``repro.spatial``.

The contract under test (module docstring of ``repro.spatial.map2d``): bulk
``sample_map`` is **elementwise identical** to the per-row row-then-column
reference — ``build_forest`` over the normalized row masses for the
marginal, one ``build_forest`` over each selected row's zero-padded
conditional at its class width for the columns — across map families (HDR
env map, one-hot texels, constant, Zipf rows) and ragged widths spanning
several size classes; **zero-mass rows are exactly unselectable** (no
epsilon) and single-texel rows resolve without special-casing;
``update_map`` is **bit-identical** to a from-scratch :class:`Map2DSampler`
over the new map while rebuilding only the dirty rows (the structural
``rebuilt_rows`` / ``skipped_rows`` witness); the 2-D QMC serving streams
are host/device **bit-equal**; and the sharded marginal agrees elementwise
with the single-device build (8-fake-device subprocess lane).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.paper_workloads import env_map_2d
from repro.core import build_forest, sample_forest
from repro.core.cdf import normalize_weights
from repro.core.metrics import chi2_statistic
from repro.serve import (
    DeviceQmc2Streams,
    Qmc2Streams,
    Request,
    ServeEngine,
    SpatialSampler,
)
from repro.spatial import Map2DSampler


def _run(script: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ, PYTHONPATH="src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=timeout,
    )


# --------------------------------------------------------------- map families


def _family(name: str):
    """Map families from the issue: each is a list of 1-D weight rows."""
    rng = np.random.default_rng(hash(name) % (2**31))
    if name == "env":
        return list(env_map_2d(12, 24))
    if name == "onehot":
        rows = []
        for r in range(9):
            w = np.zeros(17)
            w[(r * 5) % 17] = 1.0 + r
            rows.append(w)
        return rows
    if name == "constant":
        return list(np.ones((7, 33)))
    if name == "zipf":
        return [
            rng.permutation(1.0 / np.arange(1, 41) ** 1.2) for _ in range(11)
        ]
    if name == "ragged":
        # widths span classes 8/16/32/64 + zero-mass + one-hot + width-1 rows
        rows = [rng.random(w) ** 3 for w in (5, 17, 33, 8, 64, 9, 2)]
        rows.append(np.zeros(12))        # zero-mass: must never be selected
        one = np.zeros(30)
        one[13] = 2.5
        rows.append(one)                 # one-hot: always texel 13
        rows.append(np.array([4.0]))     # single-texel row (width 1)
        return rows
    raise AssertionError(name)


def _reference(rows_raw, sampler: Map2DSampler, u, v):
    """The per-row oracle: marginal ``build_forest`` over row masses, then
    one ``build_forest`` per selected row at its padded class width (class
    rows behave exactly like ``build_forest`` over the zero-padded row),
    columns clipped to the true width."""
    mass = np.asarray([r.sum() for r in rows_raw], np.float64)
    f_marg = build_forest(
        jnp.asarray(normalize_weights(mass)), sampler.m_marginal
    )
    rows = np.asarray(
        sample_forest(f_marg, jnp.asarray(u, jnp.float32)), np.int64
    )
    cols = np.empty(len(rows), np.int64)
    for r in np.unique(rows):
        mask = rows == r
        w = rows_raw[r]
        wc = int(sampler._class_of[r])
        wpad = np.pad(normalize_weights(w), (0, wc - len(w)))
        f = build_forest(jnp.asarray(wpad), wc)
        cols[mask] = np.minimum(
            np.asarray(sample_forest(f, jnp.asarray(v[mask], jnp.float32))),
            len(w) - 1,
        )
    return rows, cols


FAMILIES = ("env", "onehot", "constant", "zipf", "ragged")


@pytest.mark.parametrize("family", FAMILIES)
def test_sample_map_matches_per_row_reference(family):
    rows_raw = _family(family)
    sampler = Map2DSampler(rows_raw)
    rng = np.random.default_rng(7)
    pts = rng.random((4096, 2)).astype(np.float32)
    ri, ci, u, v = sampler.sample_map(pts)
    rr, cr = _reference(rows_raw, sampler, pts[:, 0], pts[:, 1])
    assert np.array_equal(rr, ri), f"{family}: marginal diverged"
    assert np.array_equal(cr, ci), f"{family}: conditional diverged"
    # launch-count witness: one launch per touched class, never per row
    n_classes = len({int(sampler._class_of[r]) for r in np.unique(ri)})
    assert sampler.last_drain["launches"] == (
        1 if sampler.last_drain["fused"] else n_classes
    )


def test_zero_mass_and_single_texel_rows():
    """Exact zero-mass semantics (no ``+ 1e-18``): an all-zero row's
    marginal interval has zero width, so it is NEVER selected — and one-hot
    / single-texel rows resolve to their only live texel."""
    rows_raw = _family("ragged")
    sampler = Map2DSampler(rows_raw)
    rng = np.random.default_rng(3)
    pts = rng.random((1 << 14, 2)).astype(np.float32)
    # include the adversarial corners of the unit square
    pts[:4] = [[0.0, 0.0], [0.0, 1.0 - 2**-24], [1.0 - 2**-24, 0.0],
               [1.0 - 2**-24, 1.0 - 2**-24]]
    ri, ci, _, _ = sampler.sample_map(pts)
    assert not (ri == 7).any(), "zero-mass row was selected"
    assert (ci[ri == 8] == 13).all(), "one-hot row missed its live texel"
    assert (ci[ri == 9] == 0).all(), "single-texel row returned col != 0"
    assert (ci >= 0).all()
    assert (ci < sampler.widths[ri]).all(), "col escaped its row width"


def test_single_cell_map_min_class_one():
    """Degenerate 1x1 map at min_class=1: the flat builder's n == 1 path
    (all-sentinel separators) must still resolve every point to (0, 0)."""
    sampler = Map2DSampler([np.array([3.0])], min_class=1)
    pts = np.random.default_rng(0).random((256, 2)).astype(np.float32)
    ri, ci, _, _ = sampler.sample_map(pts)
    assert (ri == 0).all() and (ci == 0).all()


def test_all_zero_map_rejected():
    with pytest.raises(ValueError):
        Map2DSampler(np.zeros((4, 8)))
    with pytest.raises(ValueError):
        Map2DSampler([np.array([1.0, -2.0])])


# -------------------------------------------------------------------- updates


def _assert_bit_identical(a: Map2DSampler, b: Map2DSampler):
    assert sorted(a.classes) == sorted(b.classes)
    for wc in a.classes:
        ca, cb = a.classes[wc], b.classes[wc]
        assert ca.row_ids == cb.row_ids
        for fa, fb in zip(ca.forest, cb.forest):
            assert np.array_equal(np.asarray(fa), np.asarray(fb)), wc
        assert np.array_equal(
            np.asarray(ca.cdf_rows).view(np.uint32),
            np.asarray(cb.cdf_rows).view(np.uint32),
        )
        assert ca.degenerate == cb.degenerate
    for k in ("cdf", "table", "left", "right", "cell_first", "fallback"):
        assert np.array_equal(
            np.asarray(getattr(a._marginal, k)),
            np.asarray(getattr(b._marginal, k)),
        ), k


def test_update_map_bit_identical_to_from_scratch():
    """Sparse ``update_map`` == from-scratch :class:`Map2DSampler` over the
    new map, bitwise over every class-forest array, the CDF skip keys, and
    the marginal — while the stats witness O(dirty rows): the unchanged
    resubmitted row skips, only the truly dirty rows rebuild."""
    rows_raw = _family("ragged")
    sampler = Map2DSampler(rows_raw)
    rng = np.random.default_rng(11)
    delta = {
        0: rng.random(5) ** 2,                # dirty (class 8)
        3: np.asarray(rows_raw[3]),           # resubmitted unchanged: skip
        4: rng.random(64) ** 2,               # dirty (class 64)
        7: rng.random(12) + 0.1,              # zero-mass row comes alive
    }
    stats = sampler.update_map(delta)
    assert stats["rebuilt_rows"] == 3
    assert stats["skipped_rows"] == 1
    # one launch per touched class (8, 16, 64) — never one per row
    assert stats["cond_launches"] == 3
    assert stats["marginal_rebuilt"] is True

    new_rows = list(rows_raw)
    for r, w in delta.items():
        new_rows[r] = np.asarray(w, np.float64)
    fresh = Map2DSampler(new_rows)
    _assert_bit_identical(sampler, fresh)

    pts = rng.random((4096, 2)).astype(np.float32)
    r1, c1, _, _ = sampler.sample_map(pts)
    r2, c2, _, _ = fresh.sample_map(pts)
    assert np.array_equal(r1, r2) and np.array_equal(c1, c2)
    assert (r1 == 7).any(), "revived row never selected after update"


def test_update_reviving_zero_row_to_uniform_skips_conditional():
    """A zero-mass row's placeholder conditional IS the uniform distribution
    — reviving it with uniform weights only moves the marginal, and the
    CDF-bits skip proves the conditional stack untouched. Still bit-identical
    to from-scratch (the placeholder normalizes to the same CDF)."""
    rows_raw = _family("ragged")
    sampler = Map2DSampler(rows_raw)
    stats = sampler.update_map({7: np.full(12, 0.25)})
    assert stats == dict(rebuilt_rows=0, skipped_rows=1, cond_launches=0,
                         marginal_rebuilt=True)
    new_rows = list(rows_raw)
    new_rows[7] = np.full(12, 0.25)
    _assert_bit_identical(sampler, Map2DSampler(new_rows))


def test_update_map_noop_and_delta_form():
    rows_raw = _family("zipf")
    sampler = Map2DSampler(rows_raw)
    stats = sampler.update_map({2: np.asarray(rows_raw[2])})
    assert stats == dict(rebuilt_rows=0, skipped_rows=1, cond_launches=0,
                         marginal_rebuilt=False)
    # additive form: img[r] += delta
    bump = np.zeros(40)
    bump[5] = 1.0
    stats = sampler.update_map({2: bump}, delta=True)
    assert stats["rebuilt_rows"] == 1 and stats["marginal_rebuilt"] is True
    fresh_rows = list(rows_raw)
    fresh_rows[2] = rows_raw[2] + bump
    _assert_bit_identical(sampler, Map2DSampler(fresh_rows))
    with pytest.raises(ValueError):
        sampler.update_map({2: np.ones(7)})  # widths are fixed
    with pytest.raises(ValueError):
        sampler.update_map({99: np.ones(40)})


# --------------------------------------------------------------- distribution


def test_map_distribution_preserved_chi2():
    """Per-texel chi-square GOF: the bulk pipeline must reproduce the full
    2-D distribution (marginal x conditional = flat texel mass)."""
    rng = np.random.default_rng(5)
    H, W = 8, 32
    img = rng.random((H, W)) ** 2 + 0.05   # bounded below: chi2 approx valid
    sampler = Map2DSampler(img)
    pts = rng.random((1 << 15, 2)).astype(np.float32)
    ri, ci, _, _ = sampler.sample_map(pts)
    counts = np.bincount(sampler.flat_index(ri, ci), minlength=H * W)
    chi2 = chi2_statistic(counts, (img / img.sum()).ravel())
    # dof = 255: mean 255, sd ~22.6; 500 is a ~10-sigma guard
    assert chi2 < 500, chi2


# ------------------------------------------------------------- serving layers


def test_qmc2_streams_host_device_bit_equal():
    """The serving contract from the 1-D streams, in 2-D: device prepass
    counters and points must be BIT-equal to the host oracle, including
    duplicate slots in one drain (occurrence-rank offsets)."""
    host = Qmc2Streams(8, seed=42)
    dev = DeviceQmc2Streams(8, seed=42)
    for slots in ([0, 3, 3, 5, 3, 0], [7, 7, 7, 7], [1]):
        s = np.asarray(slots)
        hu, hv = host.next(s)
        du, dv = dev.draw(s)
        assert np.array_equal(hu.view(np.uint32),
                              np.asarray(du).view(np.uint32))
        assert np.array_equal(hv.view(np.uint32),
                              np.asarray(dv).view(np.uint32))
    assert np.array_equal(host.counters, np.asarray(dev.counters))


def test_spatial_sampler_streams_and_update():
    img = env_map_2d(10, 20)
    a = SpatialSampler(img, n_slots=4, seed=9, device_streams=True)
    b = SpatialSampler(img, n_slots=4, seed=9, device_streams=False)
    slots = np.array([0, 2, 2, 3])
    for _ in range(3):
        assert np.array_equal(a.sample_flat(slots), b.sample_flat(slots))
    stats = a.update({1: np.full(20, 0.5)})
    assert stats["rebuilt_rows"] == 1
    flat = a.sample_flat(slots)
    assert ((0 <= flat) & (flat < img.size)).all()


def test_engine_serves_prior2d_requests():
    """Pure 2-D traffic through the engine (params=None): every emitted
    token is a valid flat texel id, zero-mass rows never appear, slots
    recycle, and a mismatched map is rejected (the map is shared)."""
    img = np.asarray(env_map_2d(9, 16))
    img[4] = 0.0                      # a dead row mid-map
    eng = ServeEngine(None, None, n_slots=4)
    reqs = [
        Request(rid=i, prompt=np.zeros(0, np.int32), max_new=5,
                prior2d=img)
        for i in range(6)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=50)
    dead_lo, dead_hi = 4 * 16, 5 * 16
    for r in reqs:
        assert r.done and len(r.out) == 5
        out = np.asarray(r.out)
        assert ((0 <= out) & (out < img.size)).all()
        assert not ((dead_lo <= out) & (out < dead_hi)).any()
    assert not eng.spatial_slots  # all retired

    other = img.copy()
    other[0, 0] += 1.0
    eng2 = ServeEngine(None, None, n_slots=2)
    eng2.submit(Request(rid=0, prompt=np.zeros(0, np.int32), prior2d=img))
    eng2.submit(Request(rid=1, prompt=np.zeros(0, np.int32), prior2d=other))
    with pytest.raises(ValueError):
        eng2.run(max_steps=5)
    with pytest.raises(ValueError):
        eng2.submit(Request(rid=2, prompt=np.zeros(0, np.int32),
                            prior=np.ones(8), prior2d=img))


# ------------------------------------------------------- sharded marginal lane


@pytest.mark.slow
def test_sharded_marginal_8_devices_subprocess():
    """The sharded marginal at 8 fake devices: ``sample_map`` rows must be
    elementwise equal to the unsharded sampler on shared uniforms (and the
    conditional path is unaffected — bit-equal columns), the zero-mass row
    stays unselectable, and a sharded ``update_map`` reports shard stats."""
    script = textwrap.dedent(
        """
        import numpy as np
        import jax
        from repro.spatial import Map2DSampler

        assert jax.device_count() == 8, jax.device_count()
        rng = np.random.default_rng(0)
        img = rng.random((32, 24)) ** 3
        img[5] = 0.0
        pts = rng.random((4096, 2)).astype(np.float32)

        plain = Map2DSampler(img)
        shard = Map2DSampler(img, sharded=True)
        assert shard.m_marginal % 8 == 0, shard.m_marginal
        r1, c1, _, _ = plain.sample_map(pts)
        r2, c2, _, _ = shard.sample_map(pts)
        assert shard.last_drain["marginal"] == "sharded"
        assert np.array_equal(r1, r2) and np.array_equal(c1, c2)
        assert not (r2 == 5).any()

        st = shard.update_map({5: rng.random(24) + 0.1, 9: img[9]})
        assert st["skipped_rows"] == 1 and st["rebuilt_rows"] == 1
        assert st["marginal_rebuilt"] and "marginal_shards" in st
        r3, _, _, _ = shard.sample_map(pts)
        assert (r3 == 5).any()
        print("SHARDED-2D-OK")
        """
    )
    res = _run(script)
    assert res.returncode == 0, res.stderr
    assert "SHARDED-2D-OK" in res.stdout
