"""Fault tolerance: kill/resume bit-equality, atomic saves, keep-k GC,
elastic restore onto a different device mesh (subprocess)."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.ckpt import latest_step, restore, save
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def _tiny_cfg():
    return dataclasses.replace(
        C.get_reduced("qwen1_5_0_5b"), dtype="float32", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
    )


def _tc(tmp, **kw):
    d = dict(steps=12, global_batch=4, seq_len=16, ckpt_dir=str(tmp / "ck"),
             ckpt_every=5, log_every=100)
    d.update(kw)
    return TrainConfig(**d)


def test_kill_and_resume_bitwise(tmp_path):
    """Crash at step 7, resume from step-5 checkpoint: final params must be
    bitwise identical to an uninterrupted run."""
    cfg = _tiny_cfg()
    ref = Trainer(cfg, _tc(tmp_path / "a"), log_fn=lambda s: None).run()

    crashy = Trainer(cfg, _tc(tmp_path / "b"), fail_at_step=7, log_fn=lambda s: None)
    with pytest.raises(RuntimeError, match="injected failure"):
        crashy.run()
    assert latest_step(str(tmp_path / "b" / "ck")) == 5
    resumed = Trainer(cfg, _tc(tmp_path / "b"), log_fn=lambda s: None).run()

    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_save_never_corrupts(tmp_path):
    tree = {"w": jnp.arange(16.0), "b": jnp.ones((4, 4))}
    save(tmp_path, tree, 1)
    # a stale tmp dir from a crashed save must be ignored by latest_step
    (tmp_path / "step_00000002.tmp").mkdir()
    assert latest_step(tmp_path) == 1
    got, step = restore(tmp_path, tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(16.0))


def test_keep_last_k(tmp_path):
    from repro.ckpt import CheckpointManager

    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(tree, s)
    steps = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_async_save_worker_failure_surfaces(tmp_path, monkeypatch):
    """An async checkpoint writer that dies (disk full, permissions) must
    NOT fail silently: the exception is re-raised on the next save()/wait()
    — a training loop can't run for hours believing checkpoints exist."""
    import repro.ckpt.checkpoint as ck

    mgr = ck.CheckpointManager(tmp_path, async_save=True)
    tree = {"x": jnp.zeros(3)}
    mgr.save(tree, 1)
    mgr.wait()  # healthy write: no error
    assert latest_step(tmp_path) == 1

    real_save = ck.save

    def boom(root, t, step):
        raise OSError("injected: no space left on device")

    monkeypatch.setattr(ck, "save", boom)
    mgr.save(tree, 2)  # worker fails in the background
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    # ...and the pending error also surfaces through the next save()
    monkeypatch.setattr(ck, "save", boom)
    mgr.save(tree, 3)
    monkeypatch.setattr(ck, "save", real_save)
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.save(tree, 4)
    # the failed steps never became visible checkpoints
    assert latest_step(tmp_path) == 1
    mgr.save(tree, 5)  # recovered: the error was consumed, not sticky
    mgr.wait()
    assert latest_step(tmp_path) == 5


def test_loss_decreases(tmp_path):
    cfg = _tiny_cfg()
    oc = AdamWConfig(lr=2e-3, total_steps=40, warmup_steps=4)
    out = Trainer(
        cfg, _tc(tmp_path, steps=40, ckpt_every=1000), oc=oc, log_fn=lambda s: None
    ).run()
    first = out["metrics"][0]["loss"]
    last = out["metrics"][-1]["loss"]
    assert last < first - 0.1, (first, last)


@pytest.mark.slow
def test_elastic_restore_other_mesh(tmp_path):
    """Save on 1 device, restore re-sharded onto an 8-device mesh in a
    subprocess (device count must be set before jax init)."""
    cfg = _tiny_cfg()
    t = Trainer(cfg, _tc(tmp_path, steps=6, ckpt_every=3), log_fn=lambda s: None)
    t.run()
    ck = str(tmp_path / "ck")

    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, numpy as np, jax.numpy as jnp
        import repro.configs as C
        from repro.ckpt import restore
        from repro.dist.sharding import Policy, param_shardings
        from repro.models import init_params
        from repro.train.optimizer import AdamWConfig, init_opt

        cfg = dataclasses.replace(
            C.get_reduced("qwen1_5_0_5b"), dtype="float32", n_layers=2,
            d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=128)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pol = Policy.for_mesh(mesh)
        p_sds = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        shard = param_shardings(mesh, p_sds, pol)
        o_sds = jax.eval_shape(lambda: init_opt(AdamWConfig(), p_sds))
        like = (p_sds, o_sds)
        (params, opt), step = restore(r"{ck}", like, shardings=None)
        # re-shard the params explicitly (elastic scaling path)
        params = jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s), params, shard)
        ndev = set()
        for leaf in jax.tree.leaves(params):
            ndev.add(len(leaf.sharding.device_set))
        assert max(ndev) > 1, ndev  # actually distributed now
        print("ELASTIC_OK", step, max(ndev))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=300,
    )
    assert "ELASTIC_OK" in proc.stdout, proc.stdout + proc.stderr


def test_restore_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.zeros((4, 4))}
    save(tmp_path, tree, 1)
    with pytest.raises(ValueError, match="shape"):
        restore(tmp_path, {"w": jnp.zeros((5, 4))})
