"""Differential suite for the stream-aware coalesced bulk drain.

The contract under test: the device-side QMC stream state
(:class:`repro.serve.sampler.DeviceQmcStreams`) is BIT-EQUAL to the host
:class:`~repro.serve.sampler.QmcStreams` oracle — offsets, counters, and
points — under duplicate-slot schedules, mixed-size-class drains, and
tenant churn; and the one-launch drain (``ForestPool.sample_streams`` ->
``forest_sample_batched_streams``) resolves exactly the draws the host
path would, with the coalescing pre-pass changing nothing elementwise.
Fast lane runs on the default backend; the slow lane re-runs the gate
under 8 fake devices in a subprocess.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.cdf import normalize_weights
from repro.core.lds import qmc_offset_bits_np
from repro.kernels import ops
from repro.pool import ForestPool, build_forest_batched
from repro.serve.sampler import DeviceQmcStreams, QmcStreams


def test_device_streams_bit_equal_host_duplicate_slots():
    """Counters and points bit-equal across drains with duplicate slots:
    the j-th occurrence of a slot must advance to counter+j on both sides,
    and the scatter-add must not collapse duplicate increments."""
    host = QmcStreams(8, seed=3)
    dev = DeviceQmcStreams(8, seed=3)
    assert np.array_equal(host.offset_bits, np.asarray(dev.offset_bits))
    schedules = [
        [0, 1, 1, 2, 1, 7],      # one slot thrice in one drain
        [3, 3, 3, 3],            # a single slot, four occurrences
        [0, 1, 2, 3, 4, 5, 6, 7],
        [5],
        [7, 0, 7, 0, 7],         # interleaved duplicates
    ]
    for sl in schedules:
        sl = np.asarray(sl)
        xh = host.next(sl)
        xd = dev.next(sl)
        assert xh.dtype == np.float32 and xd.dtype == np.float32
        assert np.array_equal(xh, xd), sl
        assert np.array_equal(host.counters, np.asarray(dev.counters)), sl
    # every point is on the 2^-24 grid (the exact fixed-point pipeline)
    got = host.next(np.arange(8))
    assert np.array_equal(got, np.float32(got * (1 << 24)) / np.float32(1 << 24))


def test_stream_kernel_matches_ref_and_is_order_invariant():
    """forest_sample_batched_streams: kernel == jnp oracle elementwise
    (indices AND in-kernel recomputed points), and the coalescing pre-pass
    (stable sort by owning tree + inverse scatter) changes nothing."""
    rng = np.random.default_rng(1)
    W = jnp.asarray(np.stack([
        normalize_weights(rng.random(24) ** 4 + 1e-9) for _ in range(5)
    ]))
    bf = build_forest_batched(W, m=32)
    Q = 96
    did = jnp.asarray(rng.integers(0, 5, Q), jnp.int32)
    ctr = jnp.asarray(rng.integers(0, 1 << 20, Q).astype(np.uint32))
    off = jnp.asarray(qmc_offset_bits_np(rng.random(Q)))
    i_ref, x_ref = ops.forest_sample_batched_streams(
        bf, did, ctr, off, use_pallas=False)
    for coalesce in (True, False):
        i_k, x_k = ops.forest_sample_batched_streams(
            bf, did, ctr, off, use_pallas=True, coalesce=coalesce)
        assert np.array_equal(np.asarray(i_k), np.asarray(i_ref)), coalesce
        assert np.array_equal(np.asarray(x_k), np.asarray(x_ref)), coalesce


def test_pool_stream_drain_mixed_classes_matches_host_path():
    """ForestPool.sample_streams over mixed size classes == the host path
    (QmcStreams.next -> ForestPool.sample) draw for draw, with the device
    twin's counters tracking the host's bit-for-bit across repeat drains."""
    rng = np.random.default_rng(7)
    pool = ForestPool(min_class=8)
    hs = pool.insert_many([rng.random(n) + 1e-3
                           for n in (5, 9, 17, 33, 6, 120)])
    host = QmcStreams(8, seed=11)
    dev = DeviceQmcStreams(8, seed=11)
    slots = np.asarray([0, 1, 2, 3, 4, 5, 0, 2])  # duplicates span classes
    handles = [hs[i % len(hs)] for i in range(len(slots))]
    for _ in range(4):
        want_xi = host.next(slots)
        want = pool.sample(handles, want_xi, use_pallas=False)
        got, got_xi = pool.sample_streams(
            handles, slots, dev, use_pallas=True, return_xi=True)
        assert np.array_equal(got_xi, want_xi)
        assert np.array_equal(got, want)
        assert np.array_equal(host.counters, np.asarray(dev.counters))


def test_pool_stream_drain_under_churn_bit_equal():
    """Tenant churn (insert/evict between drains, drifting drain lengths)
    must leave the device stream state bit-equal to the host oracle: slot
    counters belong to slots, not tenants, and survive distribution swaps
    and drain-shape rebucketing."""
    rng = np.random.default_rng(29)
    pool_a = ForestPool(min_class=8)
    pool_b = ForestPool(min_class=8)
    host = QmcStreams(16, seed=5)
    dev = DeviceQmcStreams(16, seed=5)
    live_a, live_b = [], []
    for step in range(6):
        # churn: admit a couple, evict one (both pools identically)
        for _ in range(2):
            w = rng.random(int(rng.integers(3, 70))) + 1e-3
            live_a.append(pool_a.insert(w))
            live_b.append(pool_b.insert(w))
        if step % 2 and len(live_a) > 2:
            k = int(rng.integers(0, len(live_a)))
            pool_a.evict(live_a.pop(k))
            pool_b.evict(live_b.pop(k))
        q = int(rng.integers(1, 40))  # drain length drifts across buckets
        pick = rng.integers(0, len(live_a), q)
        slots = rng.integers(0, 16, q)
        want = pool_a.sample([live_a[i] for i in pick], host.next(slots),
                             use_pallas=False)
        got = pool_b.sample_streams([live_b[i] for i in pick], slots, dev,
                                    use_pallas=True)
        assert np.array_equal(got, want), step
        assert np.array_equal(host.counters, np.asarray(dev.counters)), step


def test_stream_drain_chi_square_coalesced():
    """GOF through the coalesced stream path: each tenant's share of one
    bulk stream drain follows its own distribution (chi-square per tenant;
    the (0,1)-sequence streams are super-uniform, so the generous MC bound
    holds with room)."""
    rng = np.random.default_rng(13)
    pool = ForestPool()
    ps = [normalize_weights(rng.random(n) ** 2 + 1e-3) for n in (6, 16, 40)]
    handles = pool.insert_many(ps)
    per = 1 << 12
    qh = [h for h in handles for _ in range(per)]
    slots = np.asarray([t for t in range(len(handles)) for _ in range(per)])
    dev = DeviceQmcStreams(len(handles), seed=2)
    out = pool.sample_streams(qh, slots, dev, use_pallas=True)
    for t, p in enumerate(ps):
        counts = np.bincount(out[t * per:(t + 1) * per], minlength=len(p))
        expected = p.astype(np.float64) * per
        chi2 = float(np.sum(
            (counts - expected) ** 2 / np.maximum(expected, 1e-9)))
        assert chi2 < len(p) + 8 * np.sqrt(2 * len(p)), (t, chi2)


@pytest.mark.slow
def test_stream_drain_conformance_8dev():
    """Slow lane: the whole differential gate again under 8 fake devices —
    device/host stream bit-equality with duplicates, stream drain vs host
    path across mixed classes, coalesce on/off identity."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.pool import ForestPool
        from repro.serve.sampler import DeviceQmcStreams, QmcStreams

        assert jax.device_count() == 8
        rng = np.random.default_rng(0)
        host = QmcStreams(8, seed=3)
        dev = DeviceQmcStreams(8, seed=3)
        for sl in ([0, 1, 1, 2, 1, 7], [3, 3, 3, 3], [5]):
            sl = np.asarray(sl)
            assert np.array_equal(host.next(sl), dev.next(sl))
            assert np.array_equal(host.counters, np.asarray(dev.counters))

        pool = ForestPool(min_class=8)
        hs = pool.insert_many([rng.random(n) + 1e-3
                               for n in (5, 20, 70, 200)])
        host2 = QmcStreams(8, seed=9)
        dev2 = DeviceQmcStreams(8, seed=9)
        qh = [hs[i] for i in rng.integers(0, len(hs), 512)]
        slots = rng.integers(0, 8, 512)
        want = pool.sample(qh, host2.next(slots), use_pallas=False)
        a = pool.sample_streams(qh, slots, dev2, use_pallas=True)
        assert np.array_equal(a, want)
        assert np.array_equal(host2.counters, np.asarray(dev2.counters))
        print("STREAM_CONFORMANCE_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    p = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=900,
    )
    assert "STREAM_CONFORMANCE_OK" in p.stdout, (
        p.stdout[-2000:] + p.stderr[-4000:]
    )
