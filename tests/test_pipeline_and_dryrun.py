"""GPipe pipeline equivalence + in-process mini dry-run (both need >1 fake
device, so they run in subprocesses with the device-count flag set)."""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(script: str, devices: int = 8, timeout: int = 420):
    env = dict(os.environ, PYTHONPATH="src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=timeout,
    )


@pytest.mark.slow
def test_gpipe_matches_sequential():
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import gpipe

        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        L, M, mb, D = 8, 6, 4, 16
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(0, 0.3, (L, D, D)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (M, mb, D)), jnp.float32)

        def block(W, h):
            return jnp.tanh(h @ W)

        pipelined = gpipe(block, mesh, "pod")
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            got = jax.jit(pipelined)(Ws, x)

        want = x
        for l in range(L):
            want = jnp.tanh(want @ Ws[l])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

        # gradient flows through the ppermute schedule
        loss = lambda Ws: jnp.sum(jax.jit(pipelined)(Ws, x) ** 2)
        g = jax.grad(loss)(Ws)
        assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).sum()) > 0
        print("GPIPE_OK")
    """)
    p = _run(script)
    assert "GPIPE_OK" in p.stdout, p.stdout + p.stderr


@pytest.mark.slow
def test_mini_dryrun_in_process():
    """The dry-run machinery end-to-end on a small mesh: lower + compile a
    reduced arch on 8 fake devices, roofline terms finite and positive."""
    script = textwrap.dedent("""
        import os
        import dataclasses, jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.configs as C
        from repro.dist.sharding import Policy, batch_specs, param_shardings
        from repro.launch import roofline as R
        from repro.launch.shapes import batch_specs_struct, params_struct, ShapeSpec
        from repro.train.optimizer import AdamWConfig, init_opt
        from repro.train.step import make_train_step

        cfg = dataclasses.replace(C.get_reduced("qwen3_4b"), vocab=512)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        pol = Policy.for_mesh(mesh)
        sh = ShapeSpec("t", seq_len=64, global_batch=8, kind="train")
        p_sds = params_struct(cfg)
        p_shard = param_shardings(mesh, p_sds, pol)
        oc = AdamWConfig()
        o_sds = jax.eval_shape(lambda p: init_opt(oc, p), p_sds)
        o_shard = type(o_sds)(step=NamedSharding(mesh, P()),
                              m=param_shardings(mesh, o_sds.m, pol),
                              v=param_shardings(mesh, o_sds.v, pol))
        b_sds = batch_specs_struct(cfg, sh)
        b_shard = {k: NamedSharding(mesh, s) for k, s in batch_specs(cfg, pol).items()}
        step = make_train_step(cfg, oc, remat="dots")
        with mesh:
            compiled = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                               donate_argnums=(0, 1)).lower(p_sds, o_sds, b_sds).compile()
            roof = R.analyze(compiled, mesh, 8, trip_hints=(cfg.n_periods,),
                             analytic_flops=1e12, analytic_bytes=1e10)
        assert roof.t_compute > 0 and roof.t_mem > 0
        assert sum(c["count"] for c in roof.collectives.values()) > 0
        print("DRYRUN_OK", roof.dominant)
    """)
    p = _run(script)
    assert "DRYRUN_OK" in p.stdout, p.stdout + p.stderr


def test_hlo_collective_parser_units():
    from repro.launch.roofline import parse_collectives

    hlo = textwrap.dedent("""
        ENTRY %main (p: f32[8,8]) -> f32[8,8] {
          %all-reduce = f32[1024]{0} all-reduce(%x), replica_groups=[4,4]<=[16], metadata={op_name="jit(f)/foo"}
          %ag = f32[4096]{0} all-gather(%y), replica_groups=[2,8]<=[16], metadata={op_name="jit(f)/while/body/bar"}
        }
    """)
    c = parse_collectives(hlo, trip_hints=(10,))
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["operand_bytes"] == 4096  # 1024 f32
    # wire = 2 * R * (G-1)/G with G=4
    assert abs(c["all-reduce"]["wire_bytes"] - 2 * 4096 * 3 / 4) < 1e-6
    # all-gather inside while body: x10 trips; operand = R/G (G=8)
    assert c["all-gather"]["operand_bytes"] == 4096 * 4 / 8 * 10


def test_policy_recommended_presets():
    """Auto-policy encodes the §Perf findings (no jax device use needed)."""
    import dataclasses as dc

    import repro.configs as C
    from repro.dist.sharding import Policy

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    small = Policy.recommended(C.get("qwen1_5_0_5b"), FakeMesh(), "train")
    assert small.tp is None and small.dp == ("data", "model")

    big = Policy.recommended(C.get("kimi_k2_1t_a32b"), FakeMesh(), "train")
    assert big.tp == "model" and big.fsdp == ("data",)

    dec = Policy.recommended(C.get("llama4_maverick_400b_a17b"), FakeMesh(), "decode")
    assert dec.tp == ("data", "model") and dec.fsdp == () and dec.shard_seq

    small_dec = Policy.recommended(C.get("qwen1_5_0_5b"), FakeMesh(), "decode")
    assert small_dec.tp == "model"
