"""Conformance + regression suite for the per-tenant packed alias fast path.

The contracts under test (module docstrings of ``repro.kernels.alias_build``
/ ``alias_sample`` / ``repro.pool.arena``):

* the batched split-and-pack build (Pallas kernel AND jnp ref) is
  bit-identical between backends (shared row core), produces valid tables
  (telescoping mass) across weight families, and matches
  ``build_alias_parallel`` bit for bit on exact dyadic weights;
* ``alias_sample_batched`` agrees **elementwise** with the float32 numpy
  oracle across mixed size classes, degenerate tied rows, sentinel lanes,
  and the xi -> 1 edge;
* ``ForestPool`` treats method as a per-slot attribute: alias tenants share
  the forest pool's free-list/version machinery (stale handles raise, evict
  clears the packed row), mixed-method drains follow each tenant's own
  distribution (chi-square GOF), and the forest path is byte-identical to a
  pool that never heard of alias tables (method selection is additive);
* the serve layer threads ``method`` end to end: ``auto`` resolves by
  stream kind, and ``ServeEngine`` admission honors per-request methods.

Plus the three alias-path regressions fixed in this PR (last-cell clamp,
TokenSampler uniforms routing — pinned in test_data_and_serve — and the
dyadic boundary fix — family-tested in test_forest2d_and_extras).
"""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.alias import (
    ALIAS_FRAC_MAX,
    build_alias,
    build_alias_parallel,
    np_sample_alias,
    np_sample_alias_f32,
    sample_alias,
)
from repro.core.cdf import normalize_weights
from repro.kernels import ops
from repro.pool import BatchedAlias, ForestPool, Handle, build_alias_batched

settings = hypothesis.settings(max_examples=15, deadline=None)


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    """Drop this module's compiled programs on the way out. The suite
    compiles hundreds of XLA programs in one process; without a release
    point the accumulated compiler state can push a later module's compile
    over the edge (observed as a deterministic backend_compile segfault in
    test_stream_drain when this module precedes it)."""
    yield
    import jax

    jax.clear_caches()

_FAMILIES = ("uniform", "powerlaw", "ties", "zeros", "spike")


def _family_weights(kind: str, n: int, rng) -> np.ndarray:
    if kind == "uniform":
        return rng.random(n).astype(np.float32) + np.float32(1e-3)
    if kind == "powerlaw":
        return (rng.random(n).astype(np.float32) ** 8) + np.float32(1e-9)
    if kind == "ties":
        base = rng.random(max(n // 4, 1)).astype(np.float32) + np.float32(1e-3)
        return base[rng.integers(0, len(base), n)]
    if kind == "zeros":
        w = rng.random(n).astype(np.float32)
        w[rng.random(n) < 0.5] = 0.0
        w[rng.integers(0, n)] = 1.0
        return w
    w = np.full(n, 1e-7, np.float32)
    w[rng.integers(0, n)] = 1.0
    return w


def _mass(q, alias) -> np.ndarray:
    m = np.asarray(q, np.float64).copy()
    np.add.at(m, np.asarray(alias), 1.0 - np.asarray(q, np.float64))
    return m


# ------------------------------------------------- last-cell clamp regression


def test_sample_alias_last_cell_clamp_regression():
    """Regression: a float64 uniform just below 1 casts to float32 1.0, so
    ``scaled == n`` lands in the clipped last cell with ``frac == 1.0`` —
    pre-fix the ``frac < q`` comparison failed unconditionally and the draw
    took ``alias[n-1]`` even when the table says q == 1 (all mass in the
    cell itself). The trap table: a float64 q just below 1 casts to f32 1.0
    while its alias stays non-identity."""
    assert np.float32(1 - 2**-53) == np.float32(1.0)  # the upcast trap
    w = np.array([1 + 1e-12, 1 - 1e-12])
    t = build_alias(w)
    assert float(t.q[1]) == 1.0 and int(t.alias[1]) == 0  # trap armed
    # the limit draw xi -> 1^- must resolve to the last cell itself
    assert int(np.asarray(sample_alias(t, jnp.float32(1.0)))) == 1
    q64 = np.asarray(t.q, np.float64)
    a64 = np.asarray(t.alias)
    assert int(np_sample_alias(q64, a64, np.array([1.0]))[0]) == 1
    assert int(np_sample_alias_f32(q64, a64, np.array([1.0]))[0]) == 1


@pytest.mark.parametrize("n", [2, 3, 8, 100, 1024, 4096, 1 << 16])
def test_sample_alias_near_one_sweep(n):
    """xi = largest float32 < 1 across n sweeps: in range, matching the
    float32 numpy oracle, and landing in the last cell's own/alias pair."""
    rng = np.random.default_rng(n)
    w = rng.random(n) + 1e-3
    t = build_alias_parallel(w)
    xi = np.float32(ALIAS_FRAC_MAX)  # 1 - 2^-24
    got = int(np.asarray(sample_alias(t, jnp.asarray(xi))))
    q = np.asarray(t.q, np.float64)
    a = np.asarray(t.alias)
    want = int(np_sample_alias_f32(q, a, np.array([xi]))[0])
    assert got == want
    assert 0 <= got < n
    assert got in (n - 1, int(a[n - 1]))


# ---------------------------------------------------------- batched build


@settings
@hypothesis.given(
    kind=st.sampled_from(_FAMILIES),
    # sizes drawn from a fixed palette so the example sweep reuses a handful
    # of compiled program shapes instead of minting one per (B, n) draw
    n=st.sampled_from((2, 3, 8, 33, 96, 160)),
    B=st.sampled_from((1, 4)),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_alias_build_backends_bit_identical_and_valid(kind, n, B, seed):
    """Pallas kernel == jnp ref bit for bit (shared row core), and every
    row satisfies the telescoping-mass invariant at float32 tolerance."""
    rng = np.random.default_rng(seed)
    W = np.stack([normalize_weights(_family_weights(kind, n, rng))
                  for _ in range(B)])
    Wj = jnp.asarray(W, jnp.float32)
    q1, a1 = ops.alias_build_batched(Wj, use_pallas=False)
    q2, a2 = ops.alias_build_batched(Wj, use_pallas=True)
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    q, a = np.asarray(q1), np.asarray(a1)
    assert np.all((q >= 0.0) & (q <= 1.0))
    assert np.all((a >= 0) & (a < n))
    for b in range(B):
        w32 = W[b].astype(np.float32)
        npi = w32.astype(np.float64) / w32.sum(dtype=np.float64) * n
        np.testing.assert_allclose(_mass(q[b], a[b]), npi,
                                   rtol=2e-4, atol=2e-4)


def test_alias_build_rows_match_parallel_build_on_dyadics():
    """On exact dyadic weights the batched build must reproduce the fixed
    host ``build_alias_parallel`` bit for bit, row by row (same boundary
    policy: zero-surplus heavies owe nothing, debts skip them). Every row
    has a power-of-two total so ``npi = w/sum*n`` is exactly representable
    — off the dyadic grid the f64 host build and the f32 kernel may split
    boundary debt differently (both tables valid, same mass)."""
    rows = [
        np.array([0.25, 0.25, 0.5, 1.0]),
        np.array([1.0, 0.5, 0.25, 0.25]),
        np.array([0.5, 1.0, 0.5, 2.0]),  # zero-surplus heavy at npi == 1
        np.array([2.0, 1.0, 0.5, 0.5]),
    ]
    W = jnp.asarray(np.stack(rows), jnp.float32)
    for up in (False, True):
        q, a = ops.alias_build_batched(W, use_pallas=up)
        for b, w in enumerate(rows):
            t = build_alias_parallel(w)
            assert np.array_equal(np.asarray(q[b]), np.asarray(t.q)), (up, b)
            assert np.array_equal(np.asarray(a[b]), np.asarray(t.alias)), (up, b)


def test_alias_build_zero_padded_cells_unreachable():
    """Padded (zero-weight) cells become q == 0 lights that are never an
    alias target — no uniform can resolve to one."""
    w = np.pad(np.array([0.3, 0.5, 0.2], np.float32), (0, 5))
    bt = build_alias_batched(jnp.asarray(w[None]))
    q, a = np.asarray(bt.q[0]), np.asarray(bt.alias[0])
    assert np.all(q[3:] == 0.0)
    assert not np.any(np.isin(a, np.arange(3, 8)) & (q < 1.0))
    xi = np.linspace(0, 1, 4097, dtype=np.float32)[:-1]
    idx = np_sample_alias_f32(q, a, xi)
    assert np.all(idx < 3)


# --------------------------------------------------------- batched sampling


def test_alias_sample_batched_matches_oracle_mixed_rows():
    """Elementwise differential vs the float32 numpy oracle across mixed
    rows (incl. degenerate all-tied and spike rows), sentinel lanes, both
    backends, coalesced and scattered lane orders, and edge uniforms."""
    rng = np.random.default_rng(7)
    n = 32
    rows = [
        _family_weights("uniform", n, rng),
        np.ones(n, np.float32),                      # exactly uniform: identity
        _family_weights("ties", n, rng),
        _family_weights("spike", n, rng),
        _family_weights("zeros", n, rng),
    ]
    W = np.stack([normalize_weights(r) for r in rows])
    bt = build_alias_batched(jnp.asarray(W, jnp.float32))
    Q = 2000
    did = rng.integers(-1, len(rows), Q).astype(np.int32)
    xi = rng.random(Q).astype(np.float32)
    xi[:4] = [0.0, np.float32(ALIAS_FRAC_MAX), 1.0, 0.5]
    qn, an = np.asarray(bt.q), np.asarray(bt.alias)
    want = np.array(
        [np_sample_alias_f32(qn[d], an[d], np.array([x]))[0] if d >= 0 else 0
         for d, x in zip(did, xi)],
        np.int32,
    )
    for up in (False, True):
        for co in (False, True):
            got = np.asarray(ops.alias_sample_batched(
                bt, jnp.asarray(did), jnp.asarray(xi),
                use_pallas=up, coalesce=co,
            ))
            assert np.array_equal(got, want), (up, co)


# ------------------------------------------------------------- pool arena


def test_pool_alias_handles_and_rows():
    """Alias tenants pack into their own arenas; every occupied row is
    bit-identical to a standalone batched build of the padded weights."""
    rng = np.random.default_rng(3)
    pool = ForestPool()
    tenants = [rng.random(s) + 1e-3 for s in (5, 12, 40, 100, 9)]
    hs = pool.insert_many(tenants, method="alias")
    assert all(h.method == "alias" for h in hs)
    for h, w in zip(hs, tenants):
        wn = normalize_weights(np.asarray(w, np.float64))
        padded = np.pad(wn, (0, h.size_class - len(wn))).astype(np.float32)
        solo = build_alias_batched(jnp.asarray(padded[None]))
        t = pool.alias_row(h)
        assert np.array_equal(np.asarray(t.q), np.asarray(solo.q[0]))
        assert np.array_equal(np.asarray(t.alias), np.asarray(solo.alias[0]))
    st_ = pool.stats()
    assert st_["tenants"] == len(tenants)
    assert st_["classes"] == {}  # no forest arena was ever touched
    assert sum(c["occupied"] for c in st_["alias_classes"].values()) == len(tenants)


def test_pool_alias_lifecycle_invariants():
    """Free-list reuse bumps versions; stale alias handles raise on every
    entry point; evict zeroes the packed row; method mismatch raises."""
    rng = np.random.default_rng(4)
    pool = ForestPool()
    hs = pool.insert_many([rng.random(10) + 1e-3 for _ in range(3)],
                          method="alias")
    victim = hs[1]
    row = victim.row
    pool.evict(victim)
    ar = pool.alias_classes[victim.size_class]
    assert not np.asarray(ar.table.q[row]).any()       # cleared
    assert not np.asarray(ar.table.alias[row]).any()
    for fn in (
        lambda: pool.sample([victim], [0.5]),
        lambda: pool.update_weights(victim, rng.random(10)),
        lambda: pool.alias_row(victim),
        lambda: pool.evict(victim),
    ):
        with pytest.raises(ValueError):
            fn()
    reused = pool.insert(rng.random(12) + 1e-3, method="alias")  # same class
    assert reused.row == row and reused.version == victim.version + 1
    with pytest.raises(ValueError):
        pool.forest_row(reused)  # method mismatch routes to the other view
    # padded mixed drain must not read the freed/reused row via padding
    out = pool.sample([hs[0], hs[2], reused] * 5,
                      rng.random(15).astype(np.float32))
    assert np.all(out >= 0)
    assert np.all(out[2::3] < reused.n)


def test_pool_alias_update_weights_rebuild_and_skip():
    rng = np.random.default_rng(5)
    pool = ForestPool()
    w = rng.random(20) + 1e-3
    h = pool.insert(w, method="alias")
    pool.update_weights(h, w)  # identical weights: padded row bits unchanged
    ar = pool.alias_classes[h.size_class]
    assert ar.skips == 1 and ar.rebuilds == 0
    delta = np.zeros(20)
    delta[3] = 0.7
    pool.update_weights(h, delta=delta)
    assert ar.rebuilds == 1
    new_w = normalize_weights(np.asarray(w, np.float64) + delta)
    padded = np.pad(new_w, (0, h.size_class - 20)).astype(np.float32)
    solo = build_alias_batched(jnp.asarray(padded[None]))
    t = pool.alias_row(h)
    assert np.array_equal(np.asarray(t.q), np.asarray(solo.q[0]))
    assert np.array_equal(np.asarray(t.alias), np.asarray(solo.alias[0]))


def test_pool_mixed_method_drain_matches_per_row_oracles():
    """One drain over interleaved forest/alias tenants of several size
    classes: alias lanes match the float32 numpy alias oracle, forest lanes
    match the pool's own forest-only drain — method routing cannot leak
    lanes across arenas."""
    rng = np.random.default_rng(6)
    pool = ForestPool()
    hf = pool.insert_many([rng.random(s) + 1e-3 for s in (6, 30, 90)])
    ha = pool.insert_many([rng.random(s) + 1e-3 for s in (6, 30, 90)],
                          method="alias")
    handles = [hf[0], ha[0], hf[1], ha[1], hf[2], ha[2]] * 50
    xi = rng.random(len(handles)).astype(np.float32)
    out = pool.sample(handles, xi, use_pallas=True)
    assert np.array_equal(out, pool.sample(handles, xi, use_pallas=False))
    for i, (h, x) in enumerate(zip(handles, xi)):
        if h.method == "alias":
            t = pool.alias_row(h)
            want = int(np_sample_alias_f32(
                np.asarray(t.q), np.asarray(t.alias), np.array([x])
            )[0])
            assert out[i] == min(want, h.n - 1), i
    fmask = np.array([h.method == "forest" for h in handles])
    fonly = pool.sample([h for h in handles if h.method == "forest"], xi[fmask])
    assert np.array_equal(out[fmask], fonly)


def test_forest_drains_unchanged_by_alias_tenants():
    """Method selection is additive: a pool carrying alias tenants drains
    its forest tenants bit-identically to a pool that never admitted any."""
    rng = np.random.default_rng(8)
    tenants = [rng.random(s) + 1e-3 for s in (5, 20, 70, 200)]
    pool_a, pool_b = ForestPool(), ForestPool()
    hs_a = pool_a.insert_many(tenants)
    hs_b = pool_b.insert_many(tenants)
    pool_b.insert_many([rng.random(s) + 1e-3 for s in (7, 33)], method="alias")
    qh = [rng.integers(0, len(tenants)) for _ in range(400)]
    xi = rng.random(400).astype(np.float32)
    out_a = pool_a.sample([hs_a[i] for i in qh], xi)
    out_b = pool_b.sample([hs_b[i] for i in qh], xi)
    assert np.array_equal(out_a, out_b)


def test_pool_alias_drain_chi_square():
    """Per-tenant GOF through the batched alias drain (mirror of the
    forest pool's mixed-batch chi-square): each tenant's draws follow its
    own distribution."""
    rng = np.random.default_rng(9)
    pool = ForestPool()
    tenants = [
        normalize_weights(rng.random(17) + 1e-2),
        normalize_weights(rng.random(40) ** 4 + 1e-4),
        normalize_weights(np.r_[np.ones(10), np.zeros(6)]),
    ]
    hs = pool.insert_many(tenants, method="alias")
    per = 1 << 13
    handles = [h for h in hs for _ in range(per)]
    xi = rng.random(len(handles)).astype(np.float32)
    out = pool.sample(handles, xi)
    for t, (h, p) in enumerate(zip(hs, tenants)):
        idx = out[t * per:(t + 1) * per]
        counts = np.bincount(idx, minlength=len(p))
        expect = p * per
        live = expect > 0
        assert np.all(counts[~live] == 0)
        chi2 = np.sum((counts[live] - expect[live]) ** 2 / expect[live])
        dof = live.sum()
        assert chi2 < dof + 8 * np.sqrt(2 * dof), (t, chi2)


def test_handle_default_method_is_forest():
    """Back-compat: positional 4-field Handle construction still works and
    means the forest path."""
    h = Handle(8, 0, 5, 0)
    assert h.method == "forest"


# ------------------------------------------------------------ serve layer


def test_pooled_sampler_auto_method_by_stream_kind():
    from repro.serve import PooledForestSampler

    rng = np.random.default_rng(10)
    w = rng.random(12) + 1e-3
    pq = PooledForestSampler(n_slots=4, use_pallas=False)
    pp = PooledForestSampler(n_slots=4, use_pallas=False, streams="prng")
    assert pq.add(w).method == "forest"
    assert pp.add(w).method == "alias"
    assert pq.add(w, method="alias").method == "alias"   # explicit overrides
    assert pp.add(w, method="forest").method == "forest"
    with pytest.raises(ValueError):
        PooledForestSampler(streams="sobol")
    # per-tenant method sequences thread through add_many
    hs = pp.add_many([w, w, w], method=["auto", "forest", "alias"])
    assert [h.method for h in hs] == ["alias", "forest", "alias"]
    out = pp.sample(hs * 8, np.tile(np.arange(3), 8) % 4)
    assert np.all((0 <= out) & (out < 12))


def test_pooled_sampler_qmc_mixed_methods_single_drain():
    """A QMC sampler with explicitly-alias tenants still resolves the whole
    batch in one pool call; draws stay in range and the forest lanes match
    the host-stream oracle."""
    from repro.serve import PooledForestSampler
    from repro.serve.sampler import QmcStreams

    rng = np.random.default_rng(11)
    ps = PooledForestSampler(n_slots=8, seed=2, use_pallas=False)
    hf = ps.add(rng.random(20) + 1e-3)              # auto -> forest
    ha = ps.add(rng.random(20) + 1e-3, method="alias")
    handles = [hf, ha] * 32
    slots = rng.integers(0, 8, 64)
    out = ps.sample(handles, slots)
    assert np.all((0 <= out) & (out < 20))
    # forest lanes == a forest-only sampler fed the same stream points
    ps2 = PooledForestSampler(n_slots=8, seed=2, use_pallas=False,
                              device_streams=False)
    hf2 = ps2.add(rng.random(20) + 1e-3)
    host = QmcStreams(8, seed=2)
    xi = host.next(slots)
    want = ps2.pool.sample([hf2] * 64, xi)
    got2 = ps2.sample([hf2] * 64, slots)
    assert np.array_equal(got2, want)
    # and the device-stream sampler's counters advanced exactly like the
    # host oracle's despite the mixed-method drain
    assert np.array_equal(np.asarray(ps.streams.counters),
                          np.asarray(host.counters))


def test_engine_threads_per_request_method():
    from repro.serve import PooledForestSampler, Request, ServeEngine

    rng = np.random.default_rng(12)
    eng = ServeEngine(
        params=None, cfg=None, n_slots=4, max_seq=32,
        prior_sampler=PooledForestSampler(n_slots=4, use_pallas=False,
                                          streams="prng"),
    )
    reqs = [
        Request(rid=i, prompt=np.zeros(1, np.int64), max_new=5,
                prior=rng.random(rng.integers(4, 30)) + 1e-3,
                method=m)
        for i, m in enumerate(["auto", "alias", "forest", "auto", "alias"])
    ]
    for r in reqs:
        eng.submit(r)
    # after first admission, live handles carry the resolved methods
    eng.step()
    methods = {eng.slots[s].rid: h.method
               for s, h in eng.prior_handles.items()}
    for r in reqs:
        if r.rid in methods:
            want = "alias" if r.method == "auto" else r.method  # prng streams
            assert methods[r.rid] == want, r.rid
    eng.run(max_steps=100)
    assert all(r.done and len(r.out) == 5 for r in reqs)
    assert all(all(0 <= t < len(r.prior) for t in r.out) for r in reqs)
