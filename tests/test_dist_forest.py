"""Differential conformance suite for ``repro.dist.forest``.

The contract under test (module docstring of ``repro.dist.forest``): the
cell-partitioned sharded build is **bit-identical** to the single-device
``build_forest`` (cdf/table/left/right/cell_first/fallback after gather), and
owner-routed ``sample_sharded`` agrees **elementwise** with ``sample_forest``
on shared uniforms — plus chi-square goodness of fit and device-count
determinism (1 vs 8 shards).

The 8-fake-device matrix runs in subprocesses (``slow`` lane: each pays a
fresh jax init). The in-process tests run at whatever device count this
process's jax has (8 in CI via ``XLA_FLAGS``, 1 locally) so the routing and
combination logic is exercised in the fast lane too.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import (
    build_forest,
    forest_to_numpy,
    sample_forest,
    validate_forest,
)
from repro.core.cdf import build_cdf
from repro.dist import forest as DF

_KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")


def _mesh() -> Mesh:
    D = max(d for d in (1, 2, 4, 8) if d <= jax.device_count())
    return Mesh(np.array(jax.devices()[:D]), ("data",))


def _run(script: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ, PYTHONPATH="src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=timeout,
    )


# ------------------------------------------------------- in-process coverage


def test_cell_partition_contract():
    assert list(DF.cell_partition(64, 8)) == [0, 8, 16, 24, 32, 40, 48, 56, 64]
    assert list(DF.cell_partition(8, 1)) == [0, 8]
    with pytest.raises(ValueError):
        DF.cell_partition(10, 4)


def test_sharded_build_bit_identical_inprocess():
    """Build + gather == single-device build, bit for bit, at this process's
    device count; sampling agrees elementwise on shared uniforms."""
    mesh = _mesh()
    rng = np.random.default_rng(0)
    for n, m in [(13, 8), (300, 8), (300, 64), (257, 64)]:
        w = rng.random(n).astype(np.float32) ** 8 + np.float32(1e-9)
        f1 = build_forest(jnp.asarray(w), m)
        sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)
        fg = DF.gather_forest(sf)
        a, b = forest_to_numpy(f1), forest_to_numpy(fg)
        for k in _KEYS:
            assert np.array_equal(a[k], b[k]), (n, m, k)
        validate_forest(fg)
        xi = rng.random(512).astype(np.float32)
        s1 = np.asarray(sample_forest(f1, jnp.asarray(xi)))
        s2 = np.asarray(DF.sample_sharded(sf, jnp.asarray(xi), mesh=mesh))
        assert np.array_equal(s1, s2), (n, m)


def test_build_cdf_sharded_bit_identical():
    mesh = _mesh()
    rng = np.random.default_rng(3)
    for n in (1, 2, 13, 300, 4096):
        w = rng.random(n).astype(np.float32) + np.float32(1e-3)
        a = np.asarray(build_cdf(jnp.asarray(w)))
        b = np.asarray(DF.build_cdf_sharded(jnp.asarray(w), mesh=mesh))
        assert np.array_equal(a.view(np.uint32), b.view(np.uint32)), n


def test_indivisible_m_raises():
    mesh = _mesh()
    D = int(mesh.shape["data"])
    if D == 1:
        pytest.skip("every m divides a 1-way partition")
    w = jnp.asarray(np.random.default_rng(0).random(16), jnp.float32)
    with pytest.raises(ValueError):
        DF.build_forest_sharded(w, D + 1, mesh=mesh)


def test_shard_count_mismatch_raises():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices for two distinct shard counts")
    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    w = jnp.asarray(np.random.default_rng(0).random(32), jnp.float32)
    sf = DF.build_forest_sharded(w, 8, mesh=mesh1)
    with pytest.raises(ValueError):
        DF.sample_sharded(sf, jnp.zeros((4,), jnp.float32), mesh=_mesh())


def test_forest_sampler_sharded_serve_path():
    """serve.sampler.ForestSampler: the opt-in sharded guide path must draw
    exactly what the single-device path draws (same QMC streams, bit-identical
    forest)."""
    from repro.serve.sampler import ForestSampler

    w = np.random.default_rng(5).random(96) ** 6 + 1e-6
    a = ForestSampler(w, m=64, sharded=False, seed=2)
    b = ForestSampler(w, m=64, sharded=True, mesh=_mesh(), seed=2)
    slots = np.arange(32)
    for _ in range(4):
        assert np.array_equal(a.sample(slots), b.sample(slots))


def test_mixture_sampler_sharded_matches():
    from repro.data.mixture import MixtureSampler

    w = np.random.default_rng(9).random(24) + 1e-3
    a = MixtureSampler(w, m=64, seed=1)
    b = MixtureSampler(w, m=64, seed=1, sharded=True, mesh=_mesh())
    for step in (0, 7):
        assert np.array_equal(a.sample(step, 256), b.sample(step, 256))


# ------------------------------------------- 8-fake-device matrix (slow lane)

_FAMILIES = textwrap.dedent("""
    import numpy as np

    KINDS = ("uniform", "powerlaw", "ties", "zeros", "wide", "single")

    def fuzz_weights(kind, n, rng):
        if kind == "uniform":
            return rng.random(n).astype(np.float32) + np.float32(1e-3)
        if kind == "powerlaw":
            return (rng.random(n).astype(np.float32) ** 8) + np.float32(1e-9)
        if kind == "ties":
            base = rng.random(max(n // 8, 1)).astype(np.float32) + np.float32(1e-3)
            return base[rng.integers(0, len(base), n)]
        if kind == "zeros":
            w = rng.random(n).astype(np.float32)
            w[rng.random(n) < 0.5] = 0.0
            w[rng.integers(0, n)] = 1.0
            return w
        if kind == "wide":
            return (10.0 ** rng.uniform(-30, 30, n)).astype(np.float32)
        return rng.random(1).astype(np.float32) + np.float32(0.5)
""")


@pytest.mark.slow
def test_conformance_matrix_8dev():
    """The acceptance gate: PR-1 fuzz families x m in {8, 64, 1024} on 8 fake
    devices — bit-identical build, elementwise-identical sampling."""
    script = _FAMILIES + textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.core import build_forest, forest_to_numpy, sample_forest
        from repro.dist import forest as DF

        KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")
        mesh = DF.default_mesh()
        assert int(mesh.shape["data"]) == 8
        checked = 0
        for m in (8, 64, 1024):
            rng = np.random.default_rng(m)
            for kind in KINDS:
                for n in (1,) if kind == "single" else (2, 13, 300):
                    w = fuzz_weights(kind, n, rng)
                    f1 = build_forest(jnp.asarray(w), m)
                    sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)
                    fg = DF.gather_forest(sf)
                    a, b = forest_to_numpy(f1), forest_to_numpy(fg)
                    for k in KEYS:
                        assert np.array_equal(a[k], b[k]), (kind, n, m, k)
                    xi = jnp.asarray(rng.random(512).astype(np.float32))
                    s1 = np.asarray(sample_forest(f1, xi))
                    s2 = np.asarray(DF.sample_sharded(sf, xi, mesh=mesh))
                    assert np.array_equal(s1, s2), (kind, n, m)
                    checked += 1
        print("CONFORMANCE_OK", checked)
    """)
    p = _run(script)
    assert "CONFORMANCE_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]


@pytest.mark.slow
def test_chi_square_and_device_count_determinism_8dev():
    """sample_sharded draws follow the input weights (chi-square), and 1 vs 8
    shards produce identical forests AND identical samples for identical xi."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import forest_to_numpy
        from repro.core.cdf import normalize_weights
        from repro.dist import forest as DF

        rng = np.random.default_rng(7)
        p = normalize_weights(rng.random(64) ** 4 + 1e-4)
        m = 64
        mesh8 = DF.default_mesh()
        mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
        sf8 = DF.build_forest_sharded(jnp.asarray(p), m, mesh=mesh8)
        sf1 = DF.build_forest_sharded(jnp.asarray(p), m, mesh=mesh1)
        g8, g1 = DF.gather_forest(sf8), DF.gather_forest(sf1)
        a, b = forest_to_numpy(g8), forest_to_numpy(g1)
        for k in ("cdf", "table", "left", "right", "cell_first", "fallback"):
            assert np.array_equal(a[k], b[k]), k

        n_samples = 1 << 16
        xi = jnp.asarray(rng.random(n_samples).astype(np.float32))
        d8 = np.asarray(DF.sample_sharded(sf8, xi, mesh=mesh8))
        d1 = np.asarray(DF.sample_sharded(sf1, xi, mesh=mesh1))
        assert np.array_equal(d8, d1)

        counts = np.bincount(d8, minlength=64)
        expected = p * n_samples
        chi2 = float(np.sum((counts - expected) ** 2 / np.maximum(expected, 1e-9)))
        # 63 dof: mean 63, sd ~11; 200 is a ~12-sigma regression guard
        assert chi2 < 200, chi2
        print("CHI2_OK", round(chi2, 1))
    """)
    p = _run(script)
    assert "CHI2_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]


@pytest.mark.slow
def test_pallas_scan_route_8dev():
    """The kernels/cdf_scan raw-mode local scan: sharded and single-device
    paths through the SAME row-scan implementation stay bit-identical."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import build_forest_from_cdf, forest_to_numpy
        from repro.core.cdf import build_cdf
        from repro.dist import forest as DF

        rng = np.random.default_rng(11)
        w = jnp.asarray(rng.random(700).astype(np.float32) ** 6 + 1e-9)
        c1 = np.asarray(build_cdf(w, row_scan=DF.pallas_row_scan))
        c2 = np.asarray(DF.build_cdf_sharded(w, row_scan=DF.pallas_row_scan))
        assert np.array_equal(c1.view(np.uint32), c2.view(np.uint32))

        f1 = build_forest_from_cdf(jnp.asarray(c1), 64)
        sf = DF.build_forest_sharded(w, 64, row_scan=DF.pallas_row_scan)
        b = forest_to_numpy(DF.gather_forest(sf))
        a = forest_to_numpy(f1)
        for k in ("cdf", "table", "left", "right", "cell_first", "fallback"):
            assert np.array_equal(a[k], b[k]), k
        print("PALLAS_ROUTE_OK")
    """)
    p = _run(script)
    assert "PALLAS_ROUTE_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]
