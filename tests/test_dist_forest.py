"""Differential conformance suite for ``repro.dist.forest``.

The contract under test (module docstring of ``repro.dist.forest``): the
cell-partitioned **windowed** sharded build is **bit-identical** to the
single-device ``build_forest`` (cdf/table/left/right/cell_first/fallback
after gather) for equal, occupancy-rebalanced, and explicit partitions;
owner-routed ``sample_sharded`` agrees **elementwise** with ``sample_forest``
on shared uniforms; ``update_forest_sharded`` is bit-identical to a
from-scratch sharded rebuild over the same partition (including the no-op
and all-cells-changed degenerates); and the per-device build window
*provably shrinks* with the shard count (asserted on window sizes, never
wall-clock).

The 8-fake-device matrices run in subprocesses (``slow`` lane: each pays a
fresh jax init). The in-process tests run at whatever device count this
process's jax has (8 in CI via ``XLA_FLAGS``, 1 locally) so the routing,
windowing, and combination logic is exercised in the fast lane too.
"""
import itertools
import os
import subprocess
import sys
import textwrap

import hypothesis
import hypothesis.strategies as st
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh

from repro.core import (
    build_forest,
    forest_to_numpy,
    sample_forest,
    validate_forest,
)
from repro.core.cdf import build_cdf
from repro.dist import forest as DF

_KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")


def _mesh() -> Mesh:
    D = max(d for d in (1, 2, 4, 8) if d <= jax.device_count())
    return Mesh(np.array(jax.devices()[:D]), ("data",))


def _run(script: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ, PYTHONPATH="src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=timeout,
    )


def _assert_gather_bit_identical(w, m, sf):
    f1 = build_forest(jnp.asarray(w), m)
    a, b = forest_to_numpy(f1), forest_to_numpy(DF.gather_forest(sf))
    for k in _KEYS:
        assert np.array_equal(a[k], b[k]), (m, k)
    return f1


def _assert_sharded_equal(a: DF.ShardedForest, b: DF.ShardedForest):
    """Every field bitwise equal — the ShardedForest-level identity the
    delta-update contract promises (stronger than gathered identity)."""
    for k in DF.ShardedForest._fields:
        x, y = np.asarray(getattr(a, k)), np.asarray(getattr(b, k))
        assert x.dtype == y.dtype and np.array_equal(x, y), k


def _int_weights(n: int, rng) -> np.ndarray:
    """Integer-valued float32 weights with an exactly-representable scan:
    every prefix sum stays a small int, so float adds are exact and a
    +1/-1 swap between neighbors perturbs exactly one CDF entry."""
    return rng.integers(2, 50, n).astype(np.float32)


# ------------------------------------------------------- in-process coverage


def test_cell_partition_contract():
    assert list(DF.cell_partition(64, 8)) == [0, 8, 16, 24, 32, 40, 48, 56, 64]
    assert list(DF.cell_partition(8, 1)) == [0, 8]
    with pytest.raises(ValueError):
        DF.cell_partition(10, 4)


def test_sharded_build_bit_identical_inprocess():
    """Build + gather == single-device build, bit for bit, at this process's
    device count; sampling agrees elementwise on shared uniforms."""
    mesh = _mesh()
    rng = np.random.default_rng(0)
    for n, m in [(13, 8), (300, 8), (300, 64), (257, 64)]:
        w = rng.random(n).astype(np.float32) ** 8 + np.float32(1e-9)
        f1 = build_forest(jnp.asarray(w), m)
        sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)
        fg = DF.gather_forest(sf)
        a, b = forest_to_numpy(f1), forest_to_numpy(fg)
        for k in _KEYS:
            assert np.array_equal(a[k], b[k]), (n, m, k)
        validate_forest(fg)
        xi = rng.random(512).astype(np.float32)
        s1 = np.asarray(sample_forest(f1, jnp.asarray(xi)))
        s2 = np.asarray(DF.sample_sharded(sf, jnp.asarray(xi), mesh=mesh))
        assert np.array_equal(s1, s2), (n, m)


def test_build_cdf_sharded_bit_identical():
    mesh = _mesh()
    rng = np.random.default_rng(3)
    for n in (1, 2, 13, 300, 4096):
        w = rng.random(n).astype(np.float32) + np.float32(1e-3)
        a = np.asarray(build_cdf(jnp.asarray(w)))
        b = np.asarray(DF.build_cdf_sharded(jnp.asarray(w), mesh=mesh))
        assert np.array_equal(a.view(np.uint32), b.view(np.uint32)), n


def test_indivisible_m_raises():
    mesh = _mesh()
    D = int(mesh.shape["data"])
    if D == 1:
        pytest.skip("every m divides a 1-way partition")
    w = jnp.asarray(np.random.default_rng(0).random(16), jnp.float32)
    with pytest.raises(ValueError):
        DF.build_forest_sharded(w, D + 1, mesh=mesh)


def test_shard_count_mismatch_raises():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices for two distinct shard counts")
    mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
    w = jnp.asarray(np.random.default_rng(0).random(32), jnp.float32)
    sf = DF.build_forest_sharded(w, 8, mesh=mesh1)
    with pytest.raises(ValueError):
        DF.sample_sharded(sf, jnp.zeros((4,), jnp.float32), mesh=_mesh())


def test_windowed_plan_exercised_at_ambient_devices():
    """The windowed path is live at whatever device count this process has:
    the per-shard node arrays are capacity-sized windows (not (D, n) full
    copies), the owned leaf windows tile [0, n) exactly, and with more than
    one shard the static window is strictly smaller than the world."""
    mesh = _mesh()
    D = int(mesh.shape["data"])
    n, m = 2048, 512
    w = np.random.default_rng(1).random(n).astype(np.float32) + np.float32(1e-3)
    sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)
    counts = np.asarray(sf.window_count)
    starts = np.asarray(sf.window_start)
    bounds = np.asarray(sf.cell_bounds)
    assert sf.left.shape == (D, sf.capacity) == sf.right.shape
    assert counts.sum() == n == sf.n
    assert counts.max() <= sf.capacity
    assert bounds[0] == 0 and bounds[-1] == m and np.all(np.diff(bounds) >= 0)
    assert np.all(starts >= 0) and np.all(starts + sf.capacity <= n)
    if D > 1:
        # the point of the windowed refactor: per-device work < world size
        assert sf.capacity < n
    _assert_gather_bit_identical(w, m, sf)


def test_rebalanced_build_inprocess():
    """Occupancy rebalancing: bit-identity holds for unequal cell ranges and
    the rebalanced capacity never exceeds the equal-partition capacity (the
    load-balance objective, monotone under capacity rounding)."""
    mesh = _mesh()
    D = int(mesh.shape["data"])
    rng = np.random.default_rng(7)
    n, m = 600, 64
    spiky = rng.random(n).astype(np.float32) * 1e-5
    spiky[rng.integers(0, n, 12)] += 50.0
    zipf = (1.0 / np.arange(1, n + 1, dtype=np.float64) ** 1.3).astype(np.float32)
    for w in (spiky, zipf):
        sf_eq = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)
        sf_rb = DF.build_forest_sharded(
            jnp.asarray(w), m, mesh=mesh, rebalance=True
        )
        assert sf_rb.capacity <= sf_eq.capacity
        f1 = _assert_gather_bit_identical(w, m, sf_rb)
        xi = rng.random(512).astype(np.float32)
        s1 = np.asarray(sample_forest(f1, jnp.asarray(xi)))
        s2 = np.asarray(DF.sample_sharded(sf_rb, jnp.asarray(xi), mesh=mesh))
        assert np.array_equal(s1, s2)


def test_delta_update_inprocess():
    """update_forest_sharded == from-scratch rebuild over the same partition,
    as a ShardedForest (every field, bitwise), at this process's device
    count — no-op, sparse (exact integer scan, one changed CDF entry), and
    all-cells-changed."""
    mesh = _mesh()
    rng = np.random.default_rng(11)
    n, m = 1024, 64
    w0 = _int_weights(n, rng)
    sf0 = DF.build_forest_sharded(jnp.asarray(w0), m, mesh=mesh)

    # No-op: identical weights, and exact power-of-two scaling (the scan
    # scales exactly, the normalization divides it back out) — the tree
    # rebuild must not even run.
    for w_same in (w0, w0 * np.float32(2.0)):
        upd, stats = DF.update_forest_sharded(
            sf0, jnp.asarray(w_same), mesh=mesh, with_stats=True
        )
        assert not stats["rebuilt"] and stats["dirty_shards"] == 0
        _assert_sharded_equal(upd, sf0)

    # Sparse: +1/-1 between neighbors keeps every other prefix sum (and the
    # total) bit-identical, so exactly one leaf moves -> at most one shard
    # rebuilds when the window plan is unchanged.
    w1 = w0.copy()
    w1[500] += 1.0
    w1[501] -= 1.0
    upd, stats = DF.update_forest_sharded(
        sf0, jnp.asarray(w1), mesh=mesh, with_stats=True
    )
    ref = DF.build_forest_sharded(
        jnp.asarray(w1), m, mesh=mesh, partition=np.asarray(sf0.cell_bounds),
        capacity=upd.capacity,  # hysteresis may retain the larger window
    )
    _assert_sharded_equal(upd, ref)
    _assert_gather_bit_identical(w1, m, upd)
    if not stats["plan_changed"]:
        assert stats["dirty_shards"] == 1
    assert stats["dirty_chunks"] == 1

    # All cells changed: fresh random weights re-target every shard.
    w2 = rng.random(n).astype(np.float32) + np.float32(1e-3)
    upd2 = DF.update_forest_sharded(sf0, jnp.asarray(w2), mesh=mesh)
    ref2 = DF.build_forest_sharded(
        jnp.asarray(w2), m, mesh=mesh, partition=np.asarray(sf0.cell_bounds),
        capacity=upd2.capacity,
    )
    _assert_sharded_equal(upd2, ref2)
    _assert_gather_bit_identical(w2, m, upd2)

    # n must stay fixed (delta updates never resize the distribution).
    with pytest.raises(ValueError):
        DF.update_forest_sharded(sf0, jnp.asarray(w2[:-1]), mesh=mesh)


def test_delta_update_weights_delta_form():
    """The weights_delta + base_weights convenience forms the same float32
    sum the caller would."""
    mesh = _mesh()
    rng = np.random.default_rng(13)
    w0 = rng.random(256).astype(np.float32) + np.float32(1e-3)
    delta = np.zeros(256, np.float32)
    delta[10] = np.float32(0.25)
    sf0 = DF.build_forest_sharded(jnp.asarray(w0), 64, mesh=mesh)
    a = DF.update_forest_sharded(
        sf0, weights_delta=delta, base_weights=w0, mesh=mesh
    )
    b = DF.update_forest_sharded(sf0, jnp.asarray(w0) + jnp.asarray(delta),
                                 mesh=mesh)
    _assert_sharded_equal(a, b)
    with pytest.raises(ValueError):
        DF.update_forest_sharded(sf0, weights_delta=delta, mesh=mesh)


def test_capacity_hysteresis_under_alternating_stream():
    """The ROADMAP's adversarial stream: weights alternating between a
    concentrated distribution (big max-shard occupancy) and a spread one
    (small occupancy) used to re-plan the window capacity across a granule
    boundary on EVERY update, recompiling the windowed build each time.
    With hysteresis the capacity sticks at the high-water mark: no update
    recompiles (`_windowed_builder` cache misses stay flat), the kept
    capacity is reported in stats, and every step stays bit-identical to
    the single-device build."""
    mesh = _mesh()
    D = int(mesh.shape["data"])
    rng = np.random.default_rng(31)
    n, m = 1024, 64
    # concentrated: most leaves land in the first shard's cells
    w_hi = np.full(n, 1e-6, np.float32)
    w_hi[: n // 8] = 1.0
    # spread: every cell gets a similar leaf count
    w_lo = rng.random(n).astype(np.float32) + np.float32(0.5)
    sf = DF.build_forest_sharded(jnp.asarray(w_hi), m, mesh=mesh)
    cap0 = sf.capacity
    misses0 = DF._windowed_builder.cache_info().misses
    for step, w in enumerate([w_lo, w_hi, w_lo, w_hi, w_lo]):
        sf, stats = DF.update_forest_sharded(
            sf, jnp.asarray(w), mesh=mesh, with_stats=True
        )
        assert sf.capacity == cap0, (step, sf.capacity, cap0)
        assert stats["capacity"] == cap0
        _assert_gather_bit_identical(w, m, sf)
    assert DF._windowed_builder.cache_info().misses == misses0
    if D > 1:
        # the stream is genuinely adversarial: without hysteresis the
        # spread plan demands a (much) smaller window than the high-water
        # capacity kept here
        fresh = DF.build_forest_sharded(jnp.asarray(w_lo), m, mesh=mesh)
        assert fresh.capacity < cap0


def test_explicit_capacity_contract():
    """capacity= pins the static window (rounded plans reuse programs);
    too-small capacities fail loudly instead of corrupting windows."""
    mesh = _mesh()
    w = np.random.default_rng(33).random(512).astype(np.float32) + 1e-3
    sf = DF.build_forest_sharded(jnp.asarray(w), 64, mesh=mesh)
    big = DF.build_forest_sharded(jnp.asarray(w), 64, mesh=mesh,
                                  capacity=sf.n)
    assert big.capacity == sf.n
    _assert_gather_bit_identical(w, 64, big)
    max_count = int(np.asarray(sf.window_count).max())
    if max_count > 1:
        with pytest.raises(ValueError):
            DF.build_forest_sharded(jnp.asarray(w), 64, mesh=mesh,
                                    capacity=max_count - 1)


def test_forest_sampler_sharded_serve_path():
    """serve.sampler.ForestSampler: the opt-in sharded guide path must draw
    exactly what the single-device path draws (same QMC streams, bit-identical
    forest)."""
    from repro.serve.sampler import ForestSampler

    w = np.random.default_rng(5).random(96) ** 6 + 1e-6
    a = ForestSampler(w, m=64, sharded=False, seed=2)
    b = ForestSampler(w, m=64, sharded=True, mesh=_mesh(), seed=2)
    slots = np.arange(32)
    for _ in range(4):
        assert np.array_equal(a.sample(slots), b.sample(slots))


def test_forest_sampler_update_weights_matches_fresh():
    """In-place weight update on the sharded serve path: after update, the
    sampler draws exactly what a fresh sampler over the new weights draws
    (streams at the same counters), and the QMC counters are preserved."""
    from repro.serve.sampler import ForestSampler

    rng = np.random.default_rng(21)
    w0 = rng.random(80) ** 4 + 1e-6
    w1 = rng.random(80) ** 4 + 1e-6
    for sharded in (False, True):
        kw = dict(m=64, sharded=sharded, seed=3)
        if sharded:
            kw["mesh"] = _mesh()
        a = ForestSampler(w0, **kw)
        a.update_weights(w1)
        b = ForestSampler(w1, **kw)
        slots = np.arange(24)
        for _ in range(3):
            assert np.array_equal(a.sample(slots), b.sample(slots))
        # delta form: additive on the raw weights
        c = ForestSampler(w0, **kw)
        c.update_weights(delta=w1 - w0)
        d = ForestSampler(w1, **kw)
        for _ in range(2):
            assert np.array_equal(c.sample(slots), d.sample(slots))
        with pytest.raises(ValueError):
            c.update_weights(w1, delta=w1 - w0)  # ambiguous: exactly one
        with pytest.raises(ValueError):
            c.update_weights()


def test_mixture_sampler_sharded_matches():
    from repro.data.mixture import MixtureSampler

    w = np.random.default_rng(9).random(24) + 1e-3
    a = MixtureSampler(w, m=64, seed=1)
    b = MixtureSampler(w, m=64, seed=1, sharded=True, mesh=_mesh())
    for step in (0, 7):
        assert np.array_equal(a.sample(step, 256), b.sample(step, 256))


def test_mixture_sampler_update_weights():
    """Curriculum shift: update_weights re-targets in place; draws at any
    step equal a fresh sampler's draws over the new mixture."""
    from repro.data.mixture import MixtureSampler

    rng = np.random.default_rng(17)
    w0 = rng.random(24) + 1e-3
    w1 = rng.random(24) + 1e-3
    for sharded in (False, True):
        kw = dict(m=64, seed=1, sharded=sharded)
        if sharded:
            kw["mesh"] = _mesh()
        a = MixtureSampler(w0, **kw)
        a.update_weights(w1)
        b = MixtureSampler(w1, **kw)
        for step in (0, 5):
            assert np.array_equal(a.sample(step, 128), b.sample(step, 128))


def _drain_batches(rng, B: int = 509):
    """Routed-drain adversarial uniform batches: generic, duplicate-heavy
    (every draw repeated, exercising per-occurrence routing), and heavily
    owner-skewed (all draws land in the last shard's cells)."""
    plain = rng.random(B).astype(np.float32)
    dups = np.repeat(rng.random((B + 1) // 2).astype(np.float32), 2)[:B]
    skew = (np.float32(1.0) - rng.random(B).astype(np.float32) * 1e-4)
    return {"plain": plain, "dups": dups, "skew": skew}


def test_routed_drain_differential_inprocess():
    """Tentpole gate, fast lane: routed drain == masked-psum oracle ==
    single-device ``sample_forest`` on the gathered forest, elementwise, at
    this process's device count — over batch sizes not divisible by the
    shard count, duplicate uniforms, and all-draws-on-one-shard skew, for
    equal, rebalanced, and explicit partitions."""
    mesh = _mesh()
    D = int(mesh.shape["data"])
    rng = np.random.default_rng(41)
    n, m = 600, 64
    w = rng.random(n).astype(np.float32) ** 6 + np.float32(1e-6)
    explicit = None
    if D > 1:
        explicit = np.linspace(0, m, D + 1).astype(int)
        explicit[1] = 1  # deliberately lopsided first cell range
    for tag, kw in (
        ("equal", {}),
        ("rebalanced", {"rebalance": True}),
        ("explicit", {"partition": explicit}),
    ):
        if tag == "explicit" and explicit is None:
            continue
        sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh, **kw)
        f1 = _assert_gather_bit_identical(w, m, sf)
        for batch_tag, xi in _drain_batches(rng).items():
            want = np.asarray(sample_forest(f1, jnp.asarray(xi)))
            routed = np.asarray(
                DF.sample_sharded(sf, jnp.asarray(xi), mesh=mesh, routed=True)
            )
            oracle = np.asarray(
                DF.sample_sharded(sf, jnp.asarray(xi), mesh=mesh, routed=False)
            )
            assert np.array_equal(routed, want), (tag, batch_tag)
            assert np.array_equal(oracle, want), (tag, batch_tag)
    # tiny batches, including B < D
    sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)
    f1 = build_forest(jnp.asarray(w), m)
    for B in (1, 2, 3, D + 1):
        xi = rng.random(B).astype(np.float32)
        want = np.asarray(sample_forest(f1, jnp.asarray(xi)))
        got = np.asarray(DF.sample_sharded(sf, jnp.asarray(xi), mesh=mesh))
        assert np.array_equal(got, want), B
    with pytest.raises(ValueError):
        DF.sample_sharded(sf, jnp.zeros((0,), jnp.float32), mesh=mesh)


def test_drain_plan_structural():
    """The scaling fix, asserted on bucket *shapes* (never wall-clock): for
    balanced owner loads each shard's descent runs over a capacity-padded
    ~B/D bucket — strictly fewer lanes than the full batch the masked-psum
    oracle descends — while all-on-one-shard skew degrades gracefully to
    bucket == lanes-per-shard (never dropping a draw)."""
    mesh = _mesh()
    D = int(mesh.shape["data"])
    rng = np.random.default_rng(43)
    n = m = 1024
    w = rng.random(n).astype(np.float32) + np.float32(1e-3)
    sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)

    B = 1 << 14
    balanced = DF.drain_plan(sf, jnp.asarray(rng.random(B), jnp.float32),
                             mesh=mesh)
    assert balanced["batch"] == B
    assert balanced["padded_batch"] == balanced["lanes_per_shard"] * D >= B
    assert balanced["descent_lanes"] == D * balanced["bucket_capacity"]
    # every draw (plus padding) is accounted for in the send matrix
    assert balanced["send_counts"].shape == (D, D)
    assert balanced["send_counts"].sum() == balanced["padded_batch"]
    assert balanced["send_counts"].max() <= balanced["bucket_capacity"]
    if D > 1:
        # ~B/D descent lanes per shard vs the oracle's full-batch descent
        assert balanced["descent_lanes"] < balanced["padded_batch"]
        assert balanced["bucket_capacity"] < balanced["lanes_per_shard"]

    skew = DF.drain_plan(
        sf, jnp.asarray(np.full(B, 0.999, np.float32)), mesh=mesh
    )
    # one owner gets everything: the bucket saturates at lanes-per-shard
    assert skew["bucket_capacity"] == skew["lanes_per_shard"]

    # batch not divisible by D: padding lanes, batch preserved
    odd = DF.drain_plan(sf, jnp.asarray(rng.random(D * 16 + 3), jnp.float32),
                        mesh=mesh)
    assert odd["batch"] == D * 16 + 3
    assert odd["padded_batch"] % D == 0 and odd["padded_batch"] >= odd["batch"]


def test_sparse_delta_does_less_device_work():
    """The construction_delta,kind=sparse bug, pinned structurally: a
    one-leaf-exact perturbation with an unchanged window plan rebuilds only
    the dirty shards' windows (``rebuilt_windows == dirty_shards``), while a
    full reweight rebuilds all D — sparse does strictly less device work
    than full, asserted on rebuild counts from ``with_stats``, never
    wall-clock."""
    mesh = _mesh()
    D = int(mesh.shape["data"])
    rng = np.random.default_rng(47)
    n, m = 1024, 64
    w0 = _int_weights(n, rng)
    sf0 = DF.build_forest_sharded(jnp.asarray(w0), m, mesh=mesh)

    w1 = w0.copy()
    w1[500] += 1.0
    w1[501] -= 1.0
    upd, st = DF.update_forest_sharded(
        sf0, jnp.asarray(w1), mesh=mesh, with_stats=True
    )
    if not st["plan_changed"]:
        assert st["rebuilt_windows"] == st["dirty_shards"] == 1
        if D > 1:
            assert st["rebuilt_windows"] < D  # strictly less than kind=full
    # gating never trades away the bit-identity contract
    _assert_sharded_equal(upd, DF.build_forest_sharded(
        jnp.asarray(w1), m, mesh=mesh,
        partition=np.asarray(sf0.cell_bounds), capacity=upd.capacity,
    ))

    w2 = rng.random(n).astype(np.float32) + np.float32(1e-3)
    _, st_full = DF.update_forest_sharded(
        sf0, jnp.asarray(w2), mesh=mesh, with_stats=True
    )
    assert st_full["rebuilt_windows"] == D

    _, st_noop = DF.update_forest_sharded(
        sf0, jnp.asarray(w0), mesh=mesh, with_stats=True
    )
    assert st_noop["rebuilt_windows"] == 0


# --------------------------------------------- occupancy partition properties

settings = hypothesis.settings(max_examples=40, deadline=None)


def _optimal_max_load(counts, d: int) -> int:
    """Brute-force minimal max segment load over contiguous d-partitions."""
    cum = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    m = len(counts)
    best = int(cum[-1])
    for cuts in itertools.combinations_with_replacement(range(m + 1), d - 1):
        b = [0, *cuts, m]
        best = min(best, max(int(cum[b[i + 1]] - cum[b[i]]) for i in range(d)))
    return best


@settings
@hypothesis.given(
    counts=st.lists(st.integers(min_value=0, max_value=30),
                    min_size=1, max_size=8),
    d=st.integers(min_value=1, max_value=4),
)
def test_occupancy_partition_properties(counts, d):
    """Cell-aligned, contiguous, covers every cell, deterministic, and
    optimally balanced (brute-forced) — and the derived leaf windows tile
    the leaf space with per-shard count <= the static capacity."""
    b = DF.occupancy_partition(counts, d)
    assert b.shape == (d + 1,)
    assert b[0] == 0 and b[-1] == len(counts)      # covers all cells
    assert np.all(np.diff(b) >= 0)                 # contiguous, cell-aligned
    loads = [int(sum(counts[b[i]:b[i + 1]])) for i in range(d)]
    assert max(loads) == _optimal_max_load(counts, d)
    assert np.array_equal(b, DF.occupancy_partition(counts, d))  # deterministic

    total = int(sum(counts))
    if total:
        cells = np.repeat(np.arange(len(counts)), counts)
        starts, cnts, cap = DF._plan_windows(cells, b, total)
        assert np.array_equal(cnts, loads)
        assert cnts.max() <= cap <= total          # capacity bound, windowed
        assert starts[0] == 0 and np.all(starts[1:] == starts[:-1] + cnts[:-1])


def test_occupancy_partition_rejects_bad_input():
    with pytest.raises(ValueError):
        DF.occupancy_partition([], 2)
    with pytest.raises(ValueError):
        DF.occupancy_partition([1, 2], 0)
    with pytest.raises(ValueError):
        DF.resolve_partition(8, 2, partition=[0, 3, 7])  # doesn't reach m


# ------------------------------------------- 8-fake-device matrix (slow lane)

_FAMILIES = textwrap.dedent("""
    import numpy as np

    KINDS = ("uniform", "powerlaw", "ties", "zeros", "wide", "single")

    def fuzz_weights(kind, n, rng):
        if kind == "uniform":
            return rng.random(n).astype(np.float32) + np.float32(1e-3)
        if kind == "powerlaw":
            return (rng.random(n).astype(np.float32) ** 8) + np.float32(1e-9)
        if kind == "ties":
            base = rng.random(max(n // 8, 1)).astype(np.float32) + np.float32(1e-3)
            return base[rng.integers(0, len(base), n)]
        if kind == "zeros":
            w = rng.random(n).astype(np.float32)
            w[rng.random(n) < 0.5] = 0.0
            w[rng.integers(0, n)] = 1.0
            return w
        if kind == "wide":
            return (10.0 ** rng.uniform(-30, 30, n)).astype(np.float32)
        return rng.random(1).astype(np.float32) + np.float32(0.5)
""")

_REBAL_FAMILIES = textwrap.dedent("""
    import numpy as np

    KINDS = ("spiky", "zipf", "onehot")

    def fuzz_weights(kind, n, rng):
        if kind == "spiky":
            w = rng.random(n).astype(np.float32) * np.float32(1e-5)
            w[rng.integers(0, n, max(n // 16, 1))] += np.float32(50.0)
            return w
        if kind == "zipf":
            r = np.arange(1, n + 1, dtype=np.float64)
            return (1.0 / r ** 1.3).astype(np.float32) + np.float32(1e-12)
        w = np.full(n, 1e-7, np.float32)
        w[rng.integers(0, n)] = 1.0
        return w
""")


@pytest.mark.slow
def test_conformance_matrix_8dev():
    """The acceptance gate: PR-1 fuzz families x m in {8, 64, 1024} on 8 fake
    devices — bit-identical build, elementwise-identical sampling."""
    script = _FAMILIES + textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.core import build_forest, forest_to_numpy, sample_forest
        from repro.dist import forest as DF

        KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")
        mesh = DF.default_mesh()
        assert int(mesh.shape["data"]) == 8
        checked = 0
        for m in (8, 64, 1024):
            rng = np.random.default_rng(m)
            for kind in KINDS:
                for n in (1,) if kind == "single" else (2, 13, 300):
                    w = fuzz_weights(kind, n, rng)
                    f1 = build_forest(jnp.asarray(w), m)
                    sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh)
                    fg = DF.gather_forest(sf)
                    a, b = forest_to_numpy(f1), forest_to_numpy(fg)
                    for k in KEYS:
                        assert np.array_equal(a[k], b[k]), (kind, n, m, k)
                    xi = jnp.asarray(rng.random(512).astype(np.float32))
                    s1 = np.asarray(sample_forest(f1, xi))
                    s2 = np.asarray(DF.sample_sharded(sf, xi, mesh=mesh))
                    assert np.array_equal(s1, s2), (kind, n, m)
                    checked += 1
        print("CONFORMANCE_OK", checked)
    """)
    p = _run(script)
    assert "CONFORMANCE_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]


@pytest.mark.slow
def test_rebalanced_matrix_and_window_shrink_8dev():
    """Rebalanced-partition fuzz matrix: spiky/Zipf/one-hot x m in
    {8, 64, 1024} x D in {1, 2, 4, 8} — occupancy-balanced windowed builds
    are bit-identical to core.build_forest and sample_sharded agrees
    elementwise. Then the scaling claim itself: for a spread distribution
    the static per-device window strictly shrinks as the shard count grows
    (window sizes, not wall-clock)."""
    script = _REBAL_FAMILIES + textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import build_forest, forest_to_numpy, sample_forest
        from repro.dist import forest as DF

        KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")
        devs = jax.devices()
        assert len(devs) == 8
        checked = 0
        for m in (8, 64, 1024):
            rng = np.random.default_rng(m)
            for kind in KINDS:
                w = fuzz_weights(kind, 300, rng)
                f1 = build_forest(jnp.asarray(w), m)
                xi = jnp.asarray(rng.random(512).astype(np.float32))
                s1 = np.asarray(sample_forest(f1, xi))
                for D in (1, 2, 4, 8):
                    mesh = Mesh(np.array(devs[:D]), ("data",))
                    sf = DF.build_forest_sharded(
                        jnp.asarray(w), m, mesh=mesh, rebalance=True)
                    a = forest_to_numpy(f1)
                    b = forest_to_numpy(DF.gather_forest(sf))
                    for k in KEYS:
                        assert np.array_equal(a[k], b[k]), (kind, m, D, k)
                    s2 = np.asarray(DF.sample_sharded(sf, xi, mesh=mesh))
                    assert np.array_equal(s1, s2), (kind, m, D)
                    assert int(np.asarray(sf.window_count).sum()) == sf.n
                    checked += 1
        print("REBALANCE_OK", checked)

        # windowed per-device work shrinks with the shard count
        n = 4096
        w = np.random.default_rng(0).random(n).astype(np.float32) + 1e-3
        caps = []
        for D in (1, 2, 4, 8):
            mesh = Mesh(np.array(devs[:D]), ("data",))
            sf = DF.build_forest_sharded(jnp.asarray(w), n, mesh=mesh)
            caps.append(sf.capacity)
        assert caps[0] == n
        assert caps[0] > caps[1] > caps[2] > caps[3], caps
        assert caps[3] <= n // 4, caps
        print("WINDOW_SHRINK_OK", caps)
    """)
    p = _run(script)
    assert "REBALANCE_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]
    assert "WINDOW_SHRINK_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]


@pytest.mark.slow
def test_delta_update_matrix_8dev():
    """Delta-update differential gate at 8 shards: perturbations on k shards
    produce a ShardedForest bit-identical to a from-scratch sharded rebuild
    over the same partition (and a gather bit-identical to the single-device
    build) — no-op, one-leaf-exact, and all-cells-changed, over both the
    equal and the occupancy-rebalanced partition."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import build_forest, forest_to_numpy
        from repro.dist import forest as DF

        KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")
        mesh = DF.default_mesh()
        assert int(mesh.shape["data"]) == 8

        def assert_sharded_equal(a, b, tag):
            for k in DF.ShardedForest._fields:
                x, y = np.asarray(getattr(a, k)), np.asarray(getattr(b, k))
                assert np.array_equal(x, y), (tag, k)

        def assert_single_device(w, m, sf, tag):
            a = forest_to_numpy(build_forest(jnp.asarray(w), m))
            b = forest_to_numpy(DF.gather_forest(sf))
            for k in KEYS:
                assert np.array_equal(a[k], b[k]), (tag, k)

        rng = np.random.default_rng(23)
        n, m = 1024, 64
        w0 = rng.integers(2, 50, n).astype(np.float32)  # exact integer scan
        for rebalance in (False, True):
            sf0 = DF.build_forest_sharded(
                jnp.asarray(w0), m, mesh=mesh, rebalance=rebalance)
            part = np.asarray(sf0.cell_bounds)

            # no-op
            upd, st = DF.update_forest_sharded(
                sf0, jnp.asarray(w0), mesh=mesh, with_stats=True)
            assert not st["rebuilt"] and st["dirty_shards"] == 0
            assert_sharded_equal(upd, sf0, ("noop", rebalance))

            # sparse: one exact CDF entry moves -> k=1 dirty shard when the
            # window plan holds
            w1 = w0.copy(); w1[500] += 1.0; w1[501] -= 1.0
            upd, st = DF.update_forest_sharded(
                sf0, jnp.asarray(w1), mesh=mesh, with_stats=True)
            ref = DF.build_forest_sharded(
                jnp.asarray(w1), m, mesh=mesh, partition=part,
                capacity=upd.capacity)
            assert_sharded_equal(upd, ref, ("sparse", rebalance))
            assert_single_device(w1, m, upd, ("sparse", rebalance))
            if not st["plan_changed"]:
                assert st["dirty_shards"] == 1, st
                # sparse does strictly less device work than kind=full
                assert st["rebuilt_windows"] == 1 < 8, st
            assert st["dirty_chunks"] == 1, st

            # all cells changed
            w2 = rng.random(n).astype(np.float32) + np.float32(1e-3)
            upd2, st2 = DF.update_forest_sharded(
                sf0, jnp.asarray(w2), mesh=mesh, with_stats=True)
            ref2 = DF.build_forest_sharded(
                jnp.asarray(w2), m, mesh=mesh, partition=part,
                capacity=upd2.capacity)
            assert_sharded_equal(upd2, ref2, ("full", rebalance))
            assert_single_device(w2, m, upd2, ("full", rebalance))
            assert st2["rebuilt"] and st2["rebuilt_windows"] == 8
        print("DELTA_OK")
    """)
    p = _run(script)
    assert "DELTA_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]


@pytest.mark.slow
def test_routed_drain_matrix_8dev():
    """Routed-drain differential matrix at 8 fake devices: routed vs
    masked-psum oracle vs single-device ``sample_forest`` on the gathered
    forest, elementwise, across equal/rebalanced/explicit partitions x D in
    {1, 2, 4, 8} x adversarial batches (sizes not divisible by D, duplicate
    uniforms, all-draws-on-one-shard skew) — plus the structural scaling
    claim: balanced descent lanes ~B/D, skew saturating at lanes-per-shard."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import build_forest, sample_forest
        from repro.dist import forest as DF

        devs = jax.devices()
        assert len(devs) == 8
        rng = np.random.default_rng(53)
        n, m = 600, 64
        w = rng.random(n).astype(np.float32) ** 6 + np.float32(1e-6)
        f1 = build_forest(jnp.asarray(w), m)

        def batches(B=509):
            plain = rng.random(B).astype(np.float32)
            dups = np.repeat(rng.random((B + 1) // 2).astype(np.float32),
                             2)[:B]
            skew = np.float32(1.0) - rng.random(B).astype(np.float32) * 1e-4
            return {"plain": plain, "dups": dups, "skew": skew}

        checked = 0
        for D in (1, 2, 4, 8):
            mesh = Mesh(np.array(devs[:D]), ("data",))
            explicit = np.linspace(0, m, D + 1).astype(int)
            if D > 1:
                explicit[1] = 1
            for tag, kw in (("equal", {}), ("rebalanced",
                            {"rebalance": True}),
                            ("explicit", {"partition": explicit})):
                sf = DF.build_forest_sharded(jnp.asarray(w), m, mesh=mesh,
                                             **kw)
                for btag, xi in batches().items():
                    want = np.asarray(sample_forest(f1, jnp.asarray(xi)))
                    r = np.asarray(DF.sample_sharded(
                        sf, jnp.asarray(xi), mesh=mesh, routed=True))
                    o = np.asarray(DF.sample_sharded(
                        sf, jnp.asarray(xi), mesh=mesh, routed=False))
                    assert np.array_equal(r, want), (D, tag, btag)
                    assert np.array_equal(o, want), (D, tag, btag)
                    checked += 1
        print("ROUTED_OK", checked)

        # structural scaling: each shard descends ~B/D lanes, not B
        wb = rng.random(4096).astype(np.float32) + np.float32(1e-3)
        B = 1 << 14
        xi_bal = jnp.asarray(rng.random(B), jnp.float32)
        for D in (2, 4, 8):
            mesh = Mesh(np.array(devs[:D]), ("data",))
            sf = DF.build_forest_sharded(jnp.asarray(wb), 1024, mesh=mesh)
            plan = DF.drain_plan(sf, xi_bal, mesh=mesh)
            assert plan["descent_lanes"] < plan["padded_batch"], (D, plan)
            assert plan["bucket_capacity"] < plan["lanes_per_shard"], (D, plan)
            skew_plan = DF.drain_plan(
                sf, jnp.asarray(np.full(B, 0.999, np.float32)), mesh=mesh)
            assert skew_plan["bucket_capacity"] == skew_plan["lanes_per_shard"]
        print("DRAIN_SCALING_OK")
    """)
    p = _run(script)
    assert "ROUTED_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]
    assert "DRAIN_SCALING_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]


@pytest.mark.slow
def test_chi_square_and_device_count_determinism_8dev():
    """sample_sharded draws follow the input weights (chi-square), and 1 vs 8
    shards produce identical forests AND identical samples for identical xi."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import forest_to_numpy
        from repro.core.cdf import normalize_weights
        from repro.dist import forest as DF

        rng = np.random.default_rng(7)
        p = normalize_weights(rng.random(64) ** 4 + 1e-4)
        m = 64
        mesh8 = DF.default_mesh()
        mesh1 = Mesh(np.array(jax.devices()[:1]), ("data",))
        sf8 = DF.build_forest_sharded(jnp.asarray(p), m, mesh=mesh8)
        sf1 = DF.build_forest_sharded(jnp.asarray(p), m, mesh=mesh1)
        g8, g1 = DF.gather_forest(sf8), DF.gather_forest(sf1)
        a, b = forest_to_numpy(g8), forest_to_numpy(g1)
        for k in ("cdf", "table", "left", "right", "cell_first", "fallback"):
            assert np.array_equal(a[k], b[k]), k

        n_samples = 1 << 16
        xi = jnp.asarray(rng.random(n_samples).astype(np.float32))
        d8 = np.asarray(DF.sample_sharded(sf8, xi, mesh=mesh8))
        d1 = np.asarray(DF.sample_sharded(sf1, xi, mesh=mesh1))
        assert np.array_equal(d8, d1)

        counts = np.bincount(d8, minlength=64)
        expected = p * n_samples
        chi2 = float(np.sum((counts - expected) ** 2 / np.maximum(expected, 1e-9)))
        # 63 dof: mean 63, sd ~11; 200 is a ~12-sigma regression guard
        assert chi2 < 200, chi2
        print("CHI2_OK", round(chi2, 1))
    """)
    p = _run(script)
    assert "CHI2_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]


@pytest.mark.slow
def test_pallas_scan_route_8dev():
    """The kernels/cdf_scan raw-mode local scan: sharded and single-device
    paths through the SAME row-scan implementation stay bit-identical."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import build_forest_from_cdf, forest_to_numpy
        from repro.core.cdf import build_cdf
        from repro.dist import forest as DF

        rng = np.random.default_rng(11)
        w = jnp.asarray(rng.random(700).astype(np.float32) ** 6 + 1e-9)
        c1 = np.asarray(build_cdf(w, row_scan=DF.pallas_row_scan))
        c2 = np.asarray(DF.build_cdf_sharded(w, row_scan=DF.pallas_row_scan))
        assert np.array_equal(c1.view(np.uint32), c2.view(np.uint32))

        f1 = build_forest_from_cdf(jnp.asarray(c1), 64)
        sf = DF.build_forest_sharded(w, 64, row_scan=DF.pallas_row_scan)
        b = forest_to_numpy(DF.gather_forest(sf))
        a = forest_to_numpy(f1)
        for k in ("cdf", "table", "left", "right", "cell_first", "fallback"):
            assert np.array_equal(a[k], b[k]), k
        print("PALLAS_ROUTE_OK")
    """)
    p = _run(script)
    assert "PALLAS_ROUTE_OK" in p.stdout, p.stdout[-2000:] + p.stderr[-4000:]
