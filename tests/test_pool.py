"""Differential + property suite for ``repro.pool``.

The contracts under test (module docstrings of ``repro.pool.batched`` /
``repro.pool.arena``):

* the fused batched builder is **bit-identical**, row for row, to B
  independent ``core.build_forest`` calls (property-tested across weight
  families x ragged sizes, real hypothesis or the seeded stub);
* ``forest_sample_batched`` (Pallas kernel AND jnp oracle) agrees
  **elementwise** with the per-distribution reference across mixed size
  classes, including degenerate (tied-weight) rows — also under 8 fake
  devices (slow lane);
* ``ForestPool`` slot handles are stable until evicted: free-list reuse
  bumps version counters, stale handles raise, in-place weight updates
  keep the handle and reproduce a fresh build bit-for-bit, and mixed-batch
  draws follow each tenant's own distribution (chi-square GOF).
"""
import os
import subprocess
import sys
import textwrap

import hypothesis
import hypothesis.strategies as st
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import build_forest, forest_to_numpy, validate_forest
from repro.core.cdf import normalize_weights
from repro.kernels import ops, ref
from repro.pool import ForestPool, build_forest_batched

_KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")

settings = hypothesis.settings(max_examples=15, deadline=None)

_FAMILIES = ("uniform", "powerlaw", "ties", "zeros", "spike")


def _family_weights(kind: str, n: int, rng) -> np.ndarray:
    if kind == "uniform":
        return rng.random(n).astype(np.float32) + np.float32(1e-3)
    if kind == "powerlaw":
        return (rng.random(n).astype(np.float32) ** 8) + np.float32(1e-9)
    if kind == "ties":
        base = rng.random(max(n // 4, 1)).astype(np.float32) + np.float32(1e-3)
        return base[rng.integers(0, len(base), n)]
    if kind == "zeros":
        w = rng.random(n).astype(np.float32)
        w[rng.random(n) < 0.5] = 0.0
        w[rng.integers(0, n)] = 1.0
        return w
    w = np.full(n, 1e-7, np.float32)
    w[rng.integers(0, n)] = 1.0
    return w


def _assert_rows_match_single_builds(bf, W, m):
    for b in range(W.shape[0]):
        want = forest_to_numpy(build_forest(jnp.asarray(W[b]), m))
        for k in _KEYS:
            got = np.asarray(getattr(bf, k)[b])
            assert np.array_equal(got, want[k]), (b, k)


# -------------------------------------------------------- batched bit-identity


@settings
@hypothesis.given(
    kind=st.sampled_from(_FAMILIES),
    n=st.integers(min_value=1, max_value=160),
    B=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_batched_build_bit_identity_property(kind, n, B, seed):
    """Every row of the fused vmapped build == its own single build,
    bit for bit, across weight families and sizes."""
    rng = np.random.default_rng(seed)
    m = max(n, 4)
    W = np.stack([_family_weights(kind, n, rng) for _ in range(B)])
    W = np.stack([normalize_weights(w) for w in W])
    bf = build_forest_batched(jnp.asarray(W), m)
    assert bf.batch == B and bf.n == n and bf.m == m
    _assert_rows_match_single_builds(bf, W, m)


@settings
@hypothesis.given(
    sizes=st.lists(st.integers(min_value=1, max_value=120),
                   min_size=1, max_size=6),
    kind=st.sampled_from(_FAMILIES),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pool_ragged_insert_bit_identity(sizes, kind, seed):
    """Ragged tenants zero-pad into their size class; every occupied row is
    bit-identical to a standalone build of the padded weights, and the row
    validates as a well-formed forest."""
    rng = np.random.default_rng(seed)
    pool = ForestPool()
    tenants = [_family_weights(kind, s, rng) for s in sizes]
    handles = pool.insert_many(tenants)
    for h, w in zip(handles, tenants):
        assert h.size_class >= max(len(w), pool.min_class)
        wn = normalize_weights(np.asarray(w, np.float64))
        padded = np.pad(wn, (0, h.size_class - len(wn)))
        sc = pool.classes[h.size_class]
        want = forest_to_numpy(build_forest(jnp.asarray(padded), sc.m))
        got = forest_to_numpy(pool.forest_row(h))
        for k in _KEYS:
            assert np.array_equal(got[k], want[k]), (h, k)
    validate_forest(pool.forest_row(handles[0]))


# ------------------------------------------------- batched sampling kernel


@pytest.mark.parametrize("B,n,m", [(1, 8, 8), (5, 64, 32), (3, 300, 300)])
def test_forest_sample_batched_matches_per_distribution(B, n, m):
    """Kernel (interpret) == jnp oracle == per-distribution forest_sample,
    elementwise, on a mixed (dist_id, uniform) batch that includes a
    degenerate tied-weight row (fallback side-table path)."""
    rng = np.random.default_rng(B * n + m)
    W = np.stack([
        normalize_weights(_family_weights("powerlaw", n, rng))
        for _ in range(B)
    ])
    if B > 1 and n >= 4:  # force one degenerate row: exact ties chain deep
        w = np.zeros(n, np.float32)
        w[n // 2] = 1.0
        W[B - 1] = w
    bf = build_forest_batched(jnp.asarray(W), m)
    Q = 2048
    did = jnp.asarray(rng.integers(0, B, Q), jnp.int32)
    xi = jnp.asarray(rng.random(Q), jnp.float32)
    got_kernel = np.asarray(ops.forest_sample_batched(bf, did, xi,
                                                      use_pallas=True))
    got_ref = np.asarray(ops.forest_sample_batched(bf, did, xi,
                                                   use_pallas=False))
    want = np.empty(Q, np.int32)
    for b in range(B):
        sel = np.flatnonzero(np.asarray(did) == b)
        want[sel] = np.asarray(ops.forest_sample(bf.row(b), xi[sel]))
    assert np.array_equal(got_kernel, got_ref)
    assert np.array_equal(got_kernel, want)
    # the sampled interval must bracket the uniform
    cdf = np.asarray(bf.cdf)
    d, x = np.asarray(did), np.asarray(xi)
    assert np.all(cdf[d, got_kernel] <= x)
    assert np.all(x < cdf[d, got_kernel + 1])


def test_ref_forest_sample_batched_explicit_oracle():
    """The ref oracle itself against brute-force searchsorted rows (so the
    kernel test above is not two copies of one bug)."""
    rng = np.random.default_rng(5)
    B, n, m = 4, 50, 16
    W = np.stack([
        normalize_weights(rng.random(n).astype(np.float32) + 1e-3)
        for _ in range(B)
    ])
    bf = build_forest_batched(jnp.asarray(W), m)
    Q = 512
    did = jnp.asarray(rng.integers(0, B, Q), jnp.int32)
    xi = jnp.asarray(rng.random(Q), jnp.float32)
    got = np.asarray(ref.ref_forest_sample_batched(
        bf.cdf, bf.table, bf.left, bf.right, did, xi,
        bf.cell_first, bf.fallback,
    ))
    cdf = np.asarray(bf.cdf)
    for q in range(Q):
        row = cdf[int(did[q])]
        assert got[q] == np.searchsorted(row[1:], float(xi[q]), side="right")


# ----------------------------------------------------------- pool lifecycle


def test_slot_handle_invariants():
    """Eviction/reuse: rows recycle through the free list with a version
    bump; every stale-handle operation raises; arenas grow on demand."""
    rng = np.random.default_rng(7)
    pool = ForestPool(init_rows=2)
    h = [pool.insert(rng.random(12) + 1e-3) for _ in range(5)]
    sc = pool.classes[16]
    assert sc.rows == 8 and sc.grows == 2  # 2 -> 4 -> 8
    assert pool.stats()["tenants"] == 5

    pool.evict(h[1])
    for op in (
        lambda: pool.evict(h[1]),
        lambda: pool.sample([h[1]], [0.5]),
        lambda: pool.update_weights(h[1], rng.random(12)),
        lambda: pool.forest_row(h[1]),
    ):
        with pytest.raises(ValueError):
            op()

    h2 = pool.insert(rng.random(9) + 1e-3)  # same class, recycled row
    assert h2.size_class == 16
    assert h2.row == h[1].row and h2.version == h[1].version + 1
    # the recycled slot serves the NEW tenant
    out = pool.sample([h2] * 64, rng.random(64))
    assert np.all((0 <= out) & (out < 9))

    # update keeps n fixed and rejects ambiguous / broadcastable forms
    with pytest.raises(ValueError):
        pool.update_weights(h[0], rng.random(13))
    with pytest.raises(ValueError):
        pool.update_weights(h[0], delta=np.zeros(1))  # would broadcast
    with pytest.raises(ValueError):
        pool.update_weights(h[0], delta=np.zeros(16))  # padded-size slip
    with pytest.raises(ValueError):
        pool.update_weights(h[0], rng.random(12), delta=np.zeros(12))
    with pytest.raises(ValueError):
        pool.update_weights(h[0])


def test_pool_update_weights_matches_fresh_build():
    """In-place re-target == fresh padded standalone build, bit for bit;
    bit-unchanged updates skip the rebuild (delta_skips counts them)."""
    rng = np.random.default_rng(11)
    pool = ForestPool()
    w0 = rng.random(40) + 1e-3
    h = pool.insert(w0)
    sc = pool.classes[h.size_class]

    w1 = rng.random(40) + 1e-3
    pool.update_weights(h, w1)
    wn = normalize_weights(np.asarray(w1, np.float64))
    padded = np.pad(wn, (0, h.size_class - len(wn)))
    want = forest_to_numpy(build_forest(jnp.asarray(padded), sc.m))
    got = forest_to_numpy(pool.forest_row(h))
    for k in _KEYS:
        assert np.array_equal(got[k], want[k]), k
    assert sc.delta_rebuilds == 1

    # exact power-of-two scaling normalizes away: no bits move, no rebuild
    pool.update_weights(h, np.asarray(w1, np.float64) * 2.0)
    assert sc.delta_skips == 1
    got2 = forest_to_numpy(pool.forest_row(h))
    for k in _KEYS:
        assert np.array_equal(got2[k], want[k]), k

    # delta form
    d = np.zeros(40)
    d[3] = 0.5
    pool.update_weights(h, delta=d)
    wd = normalize_weights(np.asarray(w1, np.float64) * 2.0 + d)
    padded = np.pad(wd, (0, h.size_class - len(wd)))
    want = forest_to_numpy(build_forest(jnp.asarray(padded), sc.m))
    got3 = forest_to_numpy(pool.forest_row(h))
    for k in _KEYS:
        assert np.array_equal(got3[k], want[k]), k


def test_pool_mixed_batch_chi_square():
    """GOF: mixed-size-class drains follow each tenant's own distribution
    (chi-square per tenant on its share of one bulk drain)."""
    rng = np.random.default_rng(13)
    pool = ForestPool()
    ps = [
        normalize_weights(rng.random(n) ** 2 + 1e-3)
        for n in (6, 16, 40)
    ]
    handles = pool.insert_many(ps)
    per = 1 << 13
    qh = [h for h in handles for _ in range(per)]
    xi = rng.random(len(qh)).astype(np.float32)
    out = pool.sample(qh, xi, use_pallas=False)
    for t, (h, p) in enumerate(zip(handles, ps)):
        draws = out[t * per:(t + 1) * per]
        counts = np.bincount(draws, minlength=len(p))
        expected = p.astype(np.float64) * per
        chi2 = float(np.sum(
            (counts - expected) ** 2 / np.maximum(expected, 1e-9)
        ))
        # dof ~ len(p)-1 (mean ~dof, sd ~sqrt(2 dof)); generous guard
        assert chi2 < len(p) + 8 * np.sqrt(2 * len(p)), (t, chi2)


# ----------------------------------------------------------- serving wiring


def test_pooled_sampler_batched_drain_matches_manual():
    """PooledForestSampler's drain == manually inverting each tenant's
    padded forest at the same QMC stream values (the batched path changes
    the launch structure, never the draw)."""
    from repro.core import sample_forest
    from repro.serve.sampler import PooledForestSampler, QmcStreams

    rng = np.random.default_rng(17)
    ps = PooledForestSampler(n_slots=8, seed=4, use_pallas=False)
    tenants = [rng.random(n) + 1e-3 for n in (5, 30, 30, 90)]
    handles = ps.add_many(tenants)
    twin = QmcStreams(8, seed=4)
    slots = np.asarray([0, 3, 5, 6])
    for _ in range(3):
        got = ps.sample(handles, slots)
        xi = twin.next(slots)
        for i, h in enumerate(handles):
            want = int(np.asarray(sample_forest(
                ps.pool.forest_row(h), jnp.asarray([xi[i]])))[0])
            assert got[i] == min(want, h.n - 1), (i, got[i], want)


def test_evicting_degenerate_tenant_clears_fallback_tax():
    """A tied-weight tenant flags fallback cells; evicting it must clear
    the row's flags so the class's future drains skip the side-table
    bisection path (ops keys it off fallback.any() over the stack)."""
    rng = np.random.default_rng(23)
    w_tied = np.zeros(16, np.float32)
    w_tied[5] = 1.0
    pool = ForestPool()
    h_ok = pool.insert(rng.random(16) + 1e-3)
    h_deg = pool.insert(w_tied)
    sc = pool.classes[16]
    assert bool(np.asarray(sc.forest.fallback).any())
    assert sc.degenerate_rows == {h_deg.row}
    pool.evict(h_deg)
    assert not bool(np.asarray(sc.forest.fallback).any())
    assert not sc.degenerate_rows
    out = pool.sample([h_ok] * 32, rng.random(32))
    assert np.all((0 <= out) & (out < 16))


def test_padded_drain_lanes_ignore_stale_evicted_row():
    """Regression: drain padding used to fill the lane batch with dist_id 0,
    so padding lanes descended whatever row 0 currently held. After a
    mid-churn evict, row 0 holds a freed tenant's stale arrays (fallback
    cleared but the tied-chain topology intact) — padded lanes walking it
    could run past the fixed trip count and return garbage refs. Padding is
    now the sentinel dist_id -1, which resolves to a no-op leaf without
    touching any row."""
    rng = np.random.default_rng(31)
    pool = ForestPool()
    # row 0 of the 16-class: a maximally tied tenant (deep degenerate chains)
    w_tied = np.zeros(16, np.float32)
    w_tied[5] = 1.0
    h_tied = pool.insert(w_tied)
    h_live = pool.insert(rng.random(16) + 1e-3)
    assert h_tied.row == 0
    pool.evict(h_tied)  # row 0 is now stale: freed, flags cleared, trees not
    # a 3-lane drain pads to the 64 bucket -> 61 padding lanes
    u = rng.random(3).astype(np.float32)
    for use_pallas in (False, True):
        out = pool.sample([h_live] * 3, u, use_pallas=use_pallas)
        want = np.asarray(ops.forest_sample(
            pool.forest_row(h_live), jnp.asarray(u)))
        assert np.array_equal(out, np.minimum(want, 15)), use_pallas
    # same guarantee through the stream-aware drain
    from repro.serve.sampler import DeviceQmcStreams

    out = pool.sample_streams([h_live] * 3, np.asarray([0, 1, 0]),
                              DeviceQmcStreams(4, seed=1), use_pallas=True)
    assert np.all((0 <= out) & (out < 16))


def test_engine_prior_request_outlives_kv_budget():
    """max_seq is a KV budget; prior-backed slots hold no KV, so a prior
    request must produce all max_new draws even past max_seq steps."""
    from repro.serve import PooledForestSampler, Request, ServeEngine

    eng = ServeEngine(params=None, cfg=None, n_slots=2, max_seq=8,
                      prior_sampler=PooledForestSampler(
                          n_slots=2, use_pallas=False))
    req = Request(rid=0, prompt=np.zeros(1, np.int64), max_new=20,
                  prior=np.ones(5))
    eng.submit(req)
    eng.run(max_steps=60)
    assert req.done and len(req.out) == 20


def test_engine_prior_backed_requests_modelless():
    """params=None engine: pure categorical traffic through the pool —
    admission, batched drain, retirement eviction, version-safe churn."""
    from repro.serve import PooledForestSampler, Request, ServeEngine

    rng = np.random.default_rng(19)
    eng = ServeEngine(params=None, cfg=None, n_slots=3, max_seq=32,
                      prior_sampler=PooledForestSampler(
                          n_slots=3, use_pallas=False))
    reqs = [
        Request(rid=i, prompt=np.zeros(1, np.int64), max_new=4,
                prior=rng.random(rng.integers(3, 30)) + 1e-3)
        for i in range(7)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=50)
    for r in reqs:
        assert r.done and len(r.out) == 4
        assert all(0 <= t < len(r.prior) for t in r.out)
    # every tenant was evicted at retirement
    assert eng.prior_sampler.pool.stats()["tenants"] == 0
    with pytest.raises(ValueError):
        eng.submit(Request(rid=99, prompt=np.zeros(1, np.int64)))


# ------------------------------------------------- 8-fake-device (slow lane)


@pytest.mark.slow
def test_pool_conformance_8dev():
    """The acceptance gate under 8 fake devices: batched build rows stay
    bit-identical to single builds and forest_sample_batched (kernel + ref)
    agrees elementwise with the per-distribution reference across mixed
    size classes."""
    script = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import build_forest, forest_to_numpy
        from repro.core.cdf import normalize_weights
        from repro.kernels import ops
        from repro.pool import ForestPool, build_forest_batched

        assert jax.device_count() == 8
        KEYS = ("cdf", "table", "left", "right", "cell_first", "fallback")
        rng = np.random.default_rng(0)
        checked = 0
        for B, n, m in ((4, 64, 64), (3, 300, 128)):
            W = np.stack([
                normalize_weights(rng.random(n) ** 8 + 1e-9)
                for _ in range(B)
            ])
            bf = build_forest_batched(jnp.asarray(W), m)
            for b in range(B):
                want = forest_to_numpy(build_forest(jnp.asarray(W[b]), m))
                for k in KEYS:
                    assert np.array_equal(
                        np.asarray(getattr(bf, k)[b]), want[k]), (b, k)
            Q = 1024
            did = jnp.asarray(rng.integers(0, B, Q), jnp.int32)
            xi = jnp.asarray(rng.random(Q), jnp.float32)
            a = np.asarray(ops.forest_sample_batched(bf, did, xi,
                                                     use_pallas=True))
            r = np.asarray(ops.forest_sample_batched(bf, did, xi,
                                                     use_pallas=False))
            want = np.empty(Q, np.int32)
            for b in range(B):
                sel = np.flatnonzero(np.asarray(did) == b)
                want[sel] = np.asarray(ops.forest_sample(bf.row(b), xi[sel]))
            assert np.array_equal(a, r) and np.array_equal(a, want), (B, n, m)
            checked += 1

        # mixed size classes through the pool arena
        pool = ForestPool()
        hs = pool.insert_many([rng.random(s) + 1e-3 for s in (5, 20, 70, 200)])
        qh = [hs[i] for i in rng.integers(0, len(hs), 512)]
        u = rng.random(512).astype(np.float32)
        a = pool.sample(qh, u, use_pallas=True)
        b = pool.sample(qh, u, use_pallas=False)
        assert np.array_equal(a, b)
        print("POOL_CONFORMANCE_OK", checked)
    """)
    env = dict(os.environ, PYTHONPATH="src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    p = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=900,
    )
    assert "POOL_CONFORMANCE_OK" in p.stdout, (
        p.stdout[-2000:] + p.stderr[-4000:]
    )
