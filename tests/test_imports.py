"""Import hygiene: every repro.* module must import on its own.

The seed suite died at *collection* because one missing subsystem
(``repro.dist``) was pulled in transitively by the config registry. These
tests pin the fix twice over: (a) each module imports in isolation, so the
next missing dependency fails one precise test instead of cascading;
(b) the cheap entry points (configs, launch CLIs) stay decoupled from the
heavyweight model/dist imports.
"""
import importlib
import pathlib
import subprocess
import sys

import pytest

_SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


def _modules():
    out = []
    for p in sorted((_SRC / "repro").rglob("*.py")):
        rel = p.relative_to(_SRC).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        out.append(".".join(parts))
    return out


MODULES = _modules()


def test_module_list_is_nonempty():
    assert "repro.dist.sharding" in MODULES and len(MODULES) > 40
    # the pool subsystem is part of the per-module import gate
    assert {"repro.pool", "repro.pool.arena", "repro.pool.batched"} <= set(
        MODULES
    )
    # ...and so is the 2-D map serving subsystem
    assert {"repro.spatial", "repro.spatial.map2d"} <= set(MODULES)
    # ...and the serving-robustness layer
    assert {
        "repro.robust", "repro.robust.errors", "repro.robust.validate",
        "repro.robust.verify", "repro.robust.faults", "repro.robust.snapshot",
    } <= set(MODULES)


@pytest.mark.parametrize("mod", MODULES)
def test_module_imports(mod):
    importlib.import_module(mod)


def test_configs_do_not_pull_models():
    """`import repro.configs` + get() must not import repro.models.model (or
    anything behind it): a broken model/dist layer must leave the registry,
    the benchmark table configs, and `dryrun --list` usable. Subprocess so
    this process's imports don't mask the regression."""
    script = (
        "import sys\n"
        "import repro.configs as C\n"
        "C.get('qwen3_4b'); C.get('kimi_k2_1t_a32b')\n"
        "import repro.launch.dryrun\n"
        "from repro.launch.shapes import cell_matrix\n"
        "assert len(cell_matrix()) == 40\n"
        "bad = [m for m in sys.modules if m.startswith('repro.models.model')\n"
        "       or m.startswith('repro.dist')]\n"
        "assert not bad, bad\n"
        "print('HYGIENE_OK')\n"
    )
    p = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env={"PYTHONPATH": str(_SRC), "PATH": "/usr/bin:/bin"},
    )
    assert "HYGIENE_OK" in p.stdout, p.stdout + p.stderr
