"""Minimal, deterministic stand-in for the slice of the Hypothesis API this
suite uses (``given``, ``settings``, ``strategies``).

Activated by ``tests/conftest.py`` **only when the real package is absent**
(the repo rule forbids installing new dependencies into the image). Unlike
real Hypothesis there is no shrinking and no example database; examples are
drawn from a numpy ``Generator`` seeded from the test's qualified name
(crc32 — stable across processes), with boundary values mixed in so the
zero/min/max edges the property tests rely on are always exercised.
"""
from __future__ import annotations

import functools
import inspect
import zlib

from . import strategies

__version__ = "0.0-stub"


class settings:
    """``@settings`` decorator / ``settings(max_examples=...)`` factory."""

    def __init__(self, parent=None, max_examples: int = 20, deadline=None, **_kw):
        self.max_examples = (
            parent.max_examples if parent is not None else max_examples
        )

    def __call__(self, fn):
        fn._stub_settings = self
        return fn


def given(*args, **strategy_kwargs):
    if args:
        raise TypeError("hypothesis stub supports keyword strategies only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*wargs, **wkwargs):
            import numpy as np

            st = getattr(wrapper, "_stub_settings", None) or settings()
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(st.max_examples):
                example = {
                    k: s.example(rng) for k, s in strategy_kwargs.items()
                }
                try:
                    fn(*wargs, **example, **wkwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__qualname__}: "
                        f"{ {k: _short(v) for k, v in example.items()} }"
                    ) from e

        # pytest introspects the signature for fixtures: hide the params the
        # strategies supply (and __wrapped__, which wraps() sets and pytest
        # follows back to the original full signature).
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs
            ]
        )
        return wrapper

    return decorate


def _short(v):
    s = repr(v)
    return s if len(s) <= 200 else s[:200] + "..."
