"""Strategy objects for the hypothesis stub: ``example(rng)`` draws one
value. Boundary values (min/max/zero) are over-weighted relative to a pure
uniform draw, and wide float ranges are sampled on a log scale half the
time so extreme magnitudes (the 1e-30..1e30 cases) actually show up.
"""
from __future__ import annotations

import math


class SearchStrategy:
    def filter(self, predicate):
        return _Filtered(self, predicate)

    def map(self, fn):
        return _Mapped(self, fn)

    def example(self, rng):
        raise NotImplementedError


class _Filtered(SearchStrategy):
    def __init__(self, base, predicate):
        self._base, self._pred = base, predicate

    def example(self, rng):
        for _ in range(1000):
            v = self._base.example(rng)
            if self._pred(v):
                return v
        raise ValueError("filter predicate rejected 1000 consecutive examples")


class _Mapped(SearchStrategy):
    def __init__(self, base, fn):
        self._base, self._fn = base, fn

    def example(self, rng):
        return self._fn(self._base.example(rng))


class _Floats(SearchStrategy):
    def __init__(self, min_value, max_value, width):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)
        self.width = width

    def example(self, rng):
        r = rng.random()
        if r < 0.05:
            x = self.lo
        elif r < 0.10:
            x = self.hi
        elif r < 0.20 and self.lo <= 0.0 <= self.hi:
            x = 0.0
        elif r < 0.55 and self.hi > 0:
            # log-scale draw across the positive magnitudes of the range
            hi_exp = math.log10(self.hi) if self.hi > 0 else 0.0
            lo_exp = max(hi_exp - 38.0, -38.0)
            x = 10.0 ** rng.uniform(lo_exp, hi_exp)
            x = min(max(x, self.lo), self.hi)
        else:
            x = rng.uniform(self.lo, self.hi)
        if self.width == 32:
            import numpy as np

            x = float(np.float32(x))
        return min(max(x, self.lo), self.hi)


def floats(
    min_value=None,
    max_value=None,
    allow_nan=None,
    allow_infinity=None,
    width=64,
    **_kw,
):
    return _Floats(min_value, max_value, width)


_SMALL = (0, 1, 2, 3, 5, 8, 13, 20)


def _quantize(v: int, lo: int, hi: int) -> int:
    """Snap small-range draws to a geometric palette. Sizes in this suite
    become jit static args (array length n, guide size m) — unbounded
    variety means one XLA compile per example. Seed-like huge ranges pass
    through untouched."""
    if hi - lo > 10_000 or v - lo <= 4:
        return v
    step = 1
    while step * 2 <= v - lo:
        step *= 2
    return min(lo + step + (step // 2 if v - lo >= step + step // 2 else 0), hi)


class _Integers(SearchStrategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def example(self, rng):
        r = rng.random()
        if r < 0.08:
            return self.lo
        if r < 0.16:
            return self.hi
        if r < 0.4:  # small values: interesting sizes like 1, 2, 3
            return int(min(self.hi, self.lo + _SMALL[int(rng.integers(0, 6))]))
        return _quantize(
            int(rng.integers(self.lo, self.hi, endpoint=True)), self.lo, self.hi
        )


def integers(min_value=None, max_value=None):
    lo = -(2**31) if min_value is None else int(min_value)
    hi = 2**31 - 1 if max_value is None else int(max_value)
    return _Integers(lo, hi)


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size, max_size):
        self.el, self.lo, self.hi = elements, min_size, max_size

    def example(self, rng):
        r = rng.random()
        if r < 0.1:
            n = self.lo
        elif r < 0.2:
            n = self.hi
        elif r < 0.7:
            n = int(min(self.hi, self.lo + _SMALL[int(rng.integers(0, len(_SMALL)))]))
        else:
            n = _quantize(
                int(rng.integers(self.lo, self.hi, endpoint=True)),
                self.lo, self.hi,
            )
        return [self.el.example(rng) for _ in range(n)]


def lists(elements, min_size=0, max_size=50, **_kw):
    return _Lists(elements, min_size, max_size)


class _Sampled(SearchStrategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng):
        return self.options[int(rng.integers(0, len(self.options)))]


def sampled_from(options):
    return _Sampled(options)


class _Booleans(SearchStrategy):
    def example(self, rng):
        return bool(rng.integers(0, 2))


def booleans():
    return _Booleans()
