"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import build_forest, normalize_weights, sample_binary
from repro.kernels import ops, ref
from repro.kernels.cdf_scan import cdf_scan
from repro.kernels.forest_delta import forest_delta, forest_delta_update
from repro.kernels.forest_sample import forest_sample
from repro.kernels.sample_tiled import sample_rows


@pytest.mark.parametrize("B,V", [(1, 100), (4, 512), (3, 1000), (8, 4096), (2, 50257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("softmax", [True, False])
def test_cdf_scan_matches_ref(B, V, dtype, softmax):
    rng = np.random.default_rng(B * V)
    if softmax:
        x = jnp.asarray(rng.normal(0, 3, (B, V)), dtype)
    else:
        x = jnp.asarray(rng.random((B, V)) + 1e-3, dtype)
    got = cdf_scan(x, softmax=softmax, interpret=True)
    want = ref.ref_cdf_scan(x, softmax=softmax)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-6)
    assert np.all(np.diff(np.asarray(got), axis=-1) >= -1e-6)


@pytest.mark.parametrize("B,V,k", [(4, 511, 1), (2, 4096, 4), (1, 50257, 2), (16, 1024, 1)])
@pytest.mark.parametrize("tile", [128, 512])
def test_sample_rows_matches_ref(B, V, k, tile):
    rng = np.random.default_rng(V + k)
    logits = jnp.asarray(rng.normal(0, 4, (B, V)), jnp.float32)
    cdf = ref.ref_cdf_scan(logits)
    xi = jnp.asarray(rng.random((B, k)), jnp.float32)
    got = sample_rows(cdf, xi, tile=tile, interpret=True)
    want = ref.ref_sample_rows(cdf, xi)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,m,B", [(64, 16, 333), (1000, 256, 4096), (4096, 1024, 1000)])
@pytest.mark.parametrize("power", [1, 8, 20])
def test_forest_sample_kernel_matches_oracle(n, m, B, power):
    rng = np.random.default_rng(n + power)
    w = normalize_weights(rng.random(n) ** power + 1e-9)
    f = build_forest(jnp.asarray(w), m)
    xi = jnp.asarray(rng.random(B), jnp.float32)
    got = forest_sample(f.cdf, f.table, f.left, f.right, xi, interpret=True)
    oracle = sample_binary(f.cdf, xi)
    cdf = np.asarray(f.cdf)
    g, o = np.asarray(got), np.asarray(oracle)
    assert np.array_equal(g, o) or np.all(cdf[g] == cdf[o])


@pytest.mark.parametrize(
    "spec",
    [
        ("spike_at_zero", 150, None),      # 151 exact ties at 0.0
        ("interior_ties", 0, 299),         # 299 exact ties at 0.6 (left spine)
    ],
)
def test_forest_sample_kernel_degenerate_fallback(spec):
    """Exact tied weights build zero-width chains hundreds of levels deep —
    far past the kernel's ``depth=40`` trip count — and the build flags those
    cells. The kernel + ref paths with the ``cell_first``/``fallback`` side
    tables must agree *elementwise* with ``core.sample.sample_forest``
    (pre-resolution makes that true by construction). The raw no-side-table
    descent also agrees: equal split keys send every lane the same way at
    every tied node, so a tied spine collapses to <= 2 effective branches and
    the 40-trip cap is never hit by a real uniform (a finding this test
    pins — deep *leaf* depth does not imply deep *traversal*)."""
    from repro.core import sample_forest

    _, hot, hot2 = spec
    w = np.zeros(300, np.float32)
    w[hot] = 1.2
    if hot2 is not None:
        w[hot2] = 0.8
    f = build_forest(jnp.asarray(w), 16)
    assert int(np.asarray(f.fallback).sum()) >= 1
    xi = jnp.asarray(np.random.default_rng(1).random(2048), jnp.float32)
    core = np.asarray(sample_forest(f, xi))
    kern = np.asarray(
        forest_sample(
            f.cdf, f.table, f.left, f.right, xi, f.cell_first, f.fallback,
            interpret=True,
        )
    )
    refp = np.asarray(ops.forest_sample(f, xi, use_pallas=False))
    raw = np.asarray(
        forest_sample(f.cdf, f.table, f.left, f.right, xi, interpret=True)
    )
    assert np.array_equal(kern, core)
    assert np.array_equal(refp, core)
    assert np.array_equal(raw, core)
    cdf = np.asarray(f.cdf)
    xin = np.asarray(xi)
    assert np.all(cdf[kern] <= xin) and np.all(xin < cdf[kern + 1])


def test_forest_sample_kernel_deep_adversarial():
    """Distinct-key dyadic chain ~24 levels deep in ONE cell — adversarially
    close to the kernel's depth=40 cap but legitimately resolvable by pure
    descent. The raw kernel must match no-fallback core descent, and the
    side-table kernel must match fallback core (the build flags the cell:
    depth >> log2(overlap))."""
    from repro.core import depth_stats, sample_forest

    k = 24
    w = np.asarray([2.0 ** -(i + 1) for i in range(k)] + [2.0 ** -k], np.float32)
    f = build_forest(jnp.asarray(w), 1)
    assert depth_stats(f)["max_depth"] >= k
    xi = jnp.asarray(np.random.default_rng(0).random(4096), jnp.float32)
    core_fb = np.asarray(sample_forest(f, xi))
    core_raw = np.asarray(sample_forest(f, xi, use_fallback=False))
    kern_fb = np.asarray(
        forest_sample(
            f.cdf, f.table, f.left, f.right, xi, f.cell_first, f.fallback,
            interpret=True,
        )
    )
    kern_raw = np.asarray(
        forest_sample(f.cdf, f.table, f.left, f.right, xi, interpret=True)
    )
    assert np.array_equal(kern_fb, core_fb)
    assert np.array_equal(kern_raw, core_raw)
    assert np.array_equal(core_fb, core_raw)  # no zero-width ties here
    cdf = np.asarray(f.cdf)
    xin = np.asarray(xi)
    assert np.all(cdf[kern_fb] <= xin) and np.all(xin < cdf[kern_fb + 1])


@pytest.mark.parametrize("n,m", [(2, 1), (100, 7), (1023, 64), (8192, 4096)])
def test_forest_delta_matches_ref(n, m):
    rng = np.random.default_rng(n)
    data = jnp.asarray(np.sort(rng.random(n)).astype(np.float32))
    got = forest_delta(data, m, interpret=True)
    want = ref.ref_forest_delta(data, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m", [7, 64, 1024, 4096])
def test_forest_delta_matches_core_separator_distances(m):
    """The kernel must agree bitwise with the distance array the tree
    builder actually consumes (core._separator_distances over clipped
    cells) — pinned on the adversarial boundary case of a huge leading
    weight pushing every trailing tied lower bound to 1 - 2^-24, the
    closest data gets to the floor(data * m) == m edge."""
    from repro.core.cdf import build_cdf, lower_bounds
    from repro.core.forest import _cells, _separator_distances

    w = np.full(300, 1e-30, np.float32)
    w[0] = 1.0
    data = lower_bounds(build_cdf(jnp.asarray(w)))
    want = np.asarray(_separator_distances(data, _cells(data, m)))
    got = np.asarray(forest_delta(data, m, interpret=True))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        np.asarray(ref.ref_forest_delta(data, m)), want
    )


@pytest.mark.parametrize("n,m", [(2, 1), (100, 7), (1023, 64)])
def test_forest_delta_update_matches_ref(n, m):
    """The delta-update kernel: new distances == forest_delta(new data), the
    changed mask == exact bit-pattern inequality, and the pallas/ref ops
    dispatch agrees."""
    rng = np.random.default_rng(n + 1)
    old = np.sort(rng.random(n)).astype(np.float32)
    new = old.copy()
    moved = rng.random(n) < 0.3
    new[moved] = np.nextafter(new[moved], np.float32(1.0))
    d_got, c_got = forest_delta_update(
        jnp.asarray(old), jnp.asarray(new), m, interpret=True
    )
    d_ref, c_ref = ref.ref_forest_delta_update(
        jnp.asarray(old), jnp.asarray(new), m
    )
    np.testing.assert_array_equal(np.asarray(d_got), np.asarray(d_ref))
    np.testing.assert_array_equal(np.asarray(c_got), np.asarray(c_ref))
    np.testing.assert_array_equal(
        np.asarray(d_got), np.asarray(forest_delta(jnp.asarray(new), m,
                                                   interpret=True))
    )
    np.testing.assert_array_equal(
        np.asarray(c_got), old.view(np.uint32) != new.view(np.uint32)
    )
    via_ops = ops.forest_delta_update(
        jnp.asarray(old), jnp.asarray(new), m, use_pallas=False
    )
    np.testing.assert_array_equal(np.asarray(via_ops[0]), np.asarray(d_got))
    np.testing.assert_array_equal(np.asarray(via_ops[1]), np.asarray(c_got))


def test_ops_dispatch_consistency():
    """use_pallas=True/False must agree (kernel vs reference path)."""
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 2, (4, 777)), jnp.float32)
    a = ops.fused_cdf(logits, use_pallas=True)
    b = ops.fused_cdf(logits, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-6)

    xi = jnp.asarray(rng.random((4, 2)), jnp.float32)
    ia = ops.sample_rows(a, xi, use_pallas=True)
    ib = ops.sample_rows(b, xi, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


def test_end_to_end_decode_sampling_path():
    """logits -> fused CDF -> tiled sampler == softmax ground truth marginals.

    The kernel takes few uniforms per row (decode semantics), so replicate
    the row to gather S samples of one distribution.
    """
    rng = np.random.default_rng(42)
    V, S, k = 1031, 2048, 4
    logits = jnp.asarray(rng.normal(0, 2, (1, V)), jnp.float32)
    cdf = ops.fused_cdf(logits)
    p = np.asarray(jax.nn.softmax(logits, axis=-1))[0]
    rows = jnp.broadcast_to(cdf, (S // k, V))
    xi = jnp.asarray(rng.random((S // k, k)), jnp.float32)
    idx = np.asarray(ops.sample_rows(rows, xi)).ravel()
    counts = np.bincount(idx, minlength=V)
    top = p.argmax()
    exp, got = p[top] * S, counts[top]
    sd = np.sqrt(max(exp * (1 - p[top]), 1.0))
    assert abs(got - exp) < 5 * sd


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 32), (2, 96, 4, 2, 64), (1, 256, 8, 2, 32), (2, 64, 2, 1, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, KV, hd, causal, dtype):
    from repro.kernels.flash_attention import flash_attention

    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, S, KV, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    want = ref.ref_flash_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_ragged_causal():
    """Non-divisible sequence lengths exercise the padding path."""
    from repro.kernels.flash_attention import flash_attention

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (1, 100, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 100, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 100, 2, 32)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
