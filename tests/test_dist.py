"""Unit contracts for repro.dist: the hints no-op guarantee (bit-equality),
policy/sharding coverage over every arch, and compression edge cases."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.dist import hints as H
from repro.dist.compression import (
    compress_grads_with_feedback,
    dequantize_int8,
    quantize_int8,
)
from repro.dist.hints import Hints, sharding_hints
from repro.dist.sharding import Policy, batch_specs, param_shardings


def _tiny_cfg():
    return dataclasses.replace(
        C.get_reduced("qwen1_5_0_5b"), dtype="float32", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
    )


def test_hints_are_identity_outside_context():
    """The call sites in models/model.py must cost literally nothing when no
    hints are active: same object out, not a copy."""
    tree = {"embed": jnp.ones((4, 2)), "layers": {"b0": {"wq": jnp.ones(3)}}}
    assert H.gather_params(tree) is tree
    x = jnp.ones((2, 3, 4))
    assert H.act_seq(x) is x
    assert H.current_hints() is None


def test_hints_noop_bitwise():
    """Acceptance contract: a reduced-config forward pass traced inside
    ``sharding_hints`` is bit-identical to one traced without it. Fresh
    ``jax.jit`` objects per variant — hints are read at trace time, so
    reusing the module-level jit would just replay the cached executable."""
    from repro.models import init_params
    from repro.models import model as M

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32),
    }
    raw = M.forward.__wrapped__

    plain = jax.jit(raw, static_argnames=("cfg", "remat"))(params, cfg, batch)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = Policy.for_mesh(mesh)
    with mesh, sharding_hints(Hints(pol, gather_weights=True, seq_shard=True)):
        assert H.current_hints() is not None
        hinted = jax.jit(raw, static_argnames=("cfg", "remat"))(params, cfg, batch)

    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(hinted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_param_shardings_cover_every_arch():
    """Every parameter leaf of every reduced arch gets a NamedSharding whose
    spec fits the leaf's rank (rule fallthrough = replication, never a
    crash), and opt-state m/v trees shard like params."""
    from jax.sharding import NamedSharding

    from repro.launch.shapes import params_struct

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pol = Policy.for_mesh(mesh)
    for arch in C.ARCHS:
        p_sds = params_struct(C.get_reduced(arch))
        sh = param_shardings(mesh, p_sds, pol)
        assert jax.tree.structure(sh) == jax.tree.structure(p_sds), arch
        for leaf, s in zip(jax.tree.leaves(p_sds), jax.tree.leaves(sh)):
            assert isinstance(s, NamedSharding), arch
            assert len(s.spec) <= len(leaf.shape), (arch, leaf.shape, s.spec)


def test_batch_specs_keys_match_struct():
    """dryrun zips batch_specs over batch_specs_struct — keys must agree for
    every frontend/encoder combination."""
    from repro.launch.shapes import ShapeSpec, batch_specs_struct

    sh = ShapeSpec("t", seq_len=8, global_batch=4, kind="train")
    pol = Policy(dp=("data",), tp="model", fsdp=("data",))
    for arch in C.ARCHS:
        cfg = C.get_reduced(arch)
        assert set(batch_specs(cfg, pol)) == set(batch_specs_struct(cfg, sh)), arch


def test_policy_for_mesh_multipod_axes():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    pol = Policy.for_mesh(FakeMesh())
    assert pol.tp == "model" and pol.dp == ("pod", "data")
    assert pol.fsdp == ("pod", "data")
    serve = Policy.for_mesh(FakeMesh(), "decode")
    assert serve.fsdp == ()


def test_quantize_int8_zero_vector():
    q, s = quantize_int8(jnp.zeros((16,)))
    assert float(s) == 0.0
    out = np.asarray(dequantize_int8(q, s))
    assert np.all(out == 0) and np.all(np.isfinite(out))


def test_error_feedback_conserves_mass():
    """deq + residual == input (+ carried residual): nothing is lost, the
    un-applied remainder is exactly what gets carried."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(0, 1e-2, (64,)), jnp.float32)
    deq, res = compress_grads_with_feedback(g, None)
    np.testing.assert_allclose(np.asarray(deq + res), np.asarray(g), atol=1e-9)
    deq2, res2 = compress_grads_with_feedback(g, res)
    np.testing.assert_allclose(
        np.asarray(deq2 + res2), np.asarray(g + res), atol=1e-9
    )
