"""Paper §5 features: multi-row simultaneous construction, k-ary collapsing;
plus LDS generator properties and stochastic MoE routing coverage."""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cdf import normalize_weights, np_build_cdf
from repro.core.forest2d import (
    build_forest_rows,
    np_reference_rows,
    sample_forest_rows,
)
from repro.core.lds import hammersley, radical_inverse_base2, sobol
from repro.core.metrics import star_discrepancy_1d

settings = hypothesis.settings(max_examples=15, deadline=None)


@settings
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    R=st.integers(1, 12),
    W=st.integers(2, 40),
    m=st.integers(1, 64),
)
def test_multirow_forest_matches_oracle(seed, R, W, m):
    """One flat data-parallel pass == per-row searchsorted, for any grid."""
    rng = np.random.default_rng(seed)
    img = rng.random((R, W)) ** 6 + 1e-9
    cdfs = np.stack([np_build_cdf(normalize_weights(r)) for r in img])
    f = build_forest_rows(jnp.asarray(cdfs), m=m)
    B = 512
    rows = rng.integers(0, R, B).astype(np.int32)
    xi = rng.random(B).astype(np.float32)
    got = np.asarray(sample_forest_rows(f, jnp.asarray(rows), jnp.asarray(xi)))
    want = np_reference_rows(cdfs, rows, xi)
    mism = got != want
    if mism.any():  # tied zero-width intervals are equivalent
        assert all(
            cdfs[rows[i]][got[i]] == cdfs[rows[i]][want[i]]
            for i in np.where(mism)[0]
        )
    # inversion property within each row
    lo = cdfs[rows, got]
    hi = cdfs[rows, got + 1]
    assert np.all(lo <= xi) and np.all(xi < hi + 1e-7)


def test_multirow_matches_per_row_build():
    """The flat build must produce the same per-row trees as R separate
    1-D builds (the paper's equivalence claim)."""
    from repro.core import build_forest_from_cdf, sample_forest

    rng = np.random.default_rng(3)
    R, W, m = 5, 33, 16
    img = rng.random((R, W)) ** 4 + 1e-9
    cdfs = np.stack([np_build_cdf(normalize_weights(r)) for r in img])
    f2 = build_forest_rows(jnp.asarray(cdfs), m=m)
    xi = rng.random(1024).astype(np.float32)
    for r in range(R):
        f1 = build_forest_from_cdf(jnp.asarray(cdfs[r]), m)
        a = np.asarray(sample_forest(f1, jnp.asarray(xi)))
        rows = jnp.full((len(xi),), r, jnp.int32)
        b = np.asarray(sample_forest_rows(f2, rows, jnp.asarray(xi)))
        assert np.array_equal(a, b) or np.all(cdfs[r][a] == cdfs[r][b])


def test_forest2d_distribution_preserved_chi2():
    """Chi-square goodness of fit for the 2-D path, mirroring the 1-D
    ``test_distribution_preserved_chi2``: conditional column sampling within
    each row must reproduce that row's distribution."""
    rng = np.random.default_rng(11)
    R, W, m = 8, 48, 32
    img = rng.random((R, W)) ** 2 + 0.05   # bounded below: chi2 approx valid
    cdfs = np.stack([np_build_cdf(normalize_weights(r)) for r in img])
    f = build_forest_rows(jnp.asarray(cdfs), m=m)
    per_row = 1 << 13
    rows = np.repeat(np.arange(R), per_row).astype(np.int32)
    xi = rng.random(R * per_row).astype(np.float32)
    cols = np.asarray(sample_forest_rows(f, jnp.asarray(rows), jnp.asarray(xi)))
    chi2 = 0.0
    for r in range(R):
        counts = np.bincount(cols[r * per_row : (r + 1) * per_row], minlength=W)
        expected = np.diff(cdfs[r]) * per_row
        chi2 += float(np.sum((counts - expected) ** 2 / np.maximum(expected, 1e-9)))
    # dof = R*(W-1) = 376: mean 376, sd ~27.4; 650 is a ~10-sigma guard
    assert chi2 < 650, chi2


@settings
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    R=st.integers(1, 10),
    W=st.integers(2, 40),
    m=st.integers(1, 48),
)
def test_forest2d_structural_invariants(seed, R, W, m):
    """validate_forest-style invariants for the flat 2-D build: every guide
    entry resolves within its row, and in-order traversal of every (row,
    cell) tree enumerates the cell's leaves ascending behind the row-clamped
    left-overlap leaf."""
    from repro.core.forest2d import validate_forest_rows

    rng = np.random.default_rng(seed)
    img = rng.random((R, W)) ** 4 + 1e-9
    cdfs = np.stack([np_build_cdf(normalize_weights(r)) for r in img])
    f = build_forest_rows(jnp.asarray(cdfs), m=m)
    validate_forest_rows(f)


@settings
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    R=st.integers(2, 10),
    W=st.integers(2, 32),
)
def test_forest2d_marginal_conditional_consistency(seed, R, W):
    """2-D sampling factorizes (paper Sec. 5): draw the row from the marginal
    (row-mass) forest, the column from the conditional row forest. Exact
    per-draw properties: each stage satisfies its inversion bounds, and for a
    fixed row the conditional stage is a monotone map of xi (so the joint
    warp preserves LDS stratification per row)."""
    from repro.core import build_forest, sample_forest

    rng = np.random.default_rng(seed)
    img = rng.random((R, W)) ** 3 + 1e-9
    row_mass = normalize_weights(img.sum(axis=1))
    marg = build_forest(jnp.asarray(row_mass), 16)
    cond_cdfs = np.stack([np_build_cdf(normalize_weights(r)) for r in img])
    f2 = build_forest_rows(jnp.asarray(cond_cdfs), m=8)

    B = 128
    xi_r = rng.random(B).astype(np.float32)
    xi_c = np.sort(rng.random(B).astype(np.float32))
    rows = np.asarray(sample_forest(marg, jnp.asarray(xi_r)))
    marg_cdf = np.asarray(marg.cdf)
    assert np.all(marg_cdf[rows] <= xi_r) and np.all(xi_r < marg_cdf[rows + 1])

    cols = np.asarray(
        sample_forest_rows(f2, jnp.asarray(rows, jnp.int32), jnp.asarray(xi_c))
    )
    lo = cond_cdfs[rows, cols]
    hi = cond_cdfs[rows, cols + 1]
    assert np.all(lo <= xi_c) and np.all(xi_c < hi + 1e-7)

    # monotone conditional warp within one fixed row
    r0 = jnp.full((B,), int(rows[0]), jnp.int32)
    cols_fixed = np.asarray(sample_forest_rows(f2, r0, jnp.asarray(xi_c)))
    assert np.all(np.diff(cols_fixed) >= 0)


def test_forest2d_depth_bound():
    """Paper Sec. 3: per-cell traversal depth is O(log overlap), not
    O(overlap). Per-row 1-D builds are bit-identical to the flat 2-D build
    (``test_multirow_matches_per_row_build``), so bounding their
    ``depth_stats`` gates the 2-D path against linear-chain regressions:
    a degenerate chain would hit ``o_max`` (~20-26 here), far above the
    2*log2(o_max)+5 radix bound."""
    from repro.core import build_forest_from_cdf, depth_stats

    rng = np.random.default_rng(5)
    R, W, m = 6, 64, 4
    img = rng.random((R, W)) ** 6 + 1e-7
    for r in range(R):
        cdf = np_build_cdf(normalize_weights(img[r]))
        f1 = build_forest_from_cdf(jnp.asarray(cdf), m)
        ds = depth_stats(f1)
        data = cdf[:-1]
        cells = np.clip(np.floor(data * np.float32(m)).astype(int), 0, m - 1)
        o_max = int(np.bincount(cells, minlength=m).max()) + 1
        bound = 2 * int(np.ceil(np.log2(max(o_max, 2)))) + 5
        assert ds["max_depth"] <= bound, (r, ds["max_depth"], o_max, bound)
        assert o_max > bound  # the gate actually distinguishes log from linear


# ---------------------------------------------------------------- LDS props


def test_lds_low_discrepancy():
    n = 4096
    assert star_discrepancy_1d(sobol(n, 1)[:, 0]) < 0.002
    assert star_discrepancy_1d(hammersley(n, 2)[:, 1]) < 0.01
    assert star_discrepancy_1d(np.random.default_rng(0).random(n)) > 0.005


def test_sobol_high_dims_distinct_and_nondegenerate():
    """Regression: dims > 7 used to recycle direction polynomials modulo the
    table length, silently duplicating coordinate columns (every
    'independent' pair above dim 7 was perfectly correlated). The extended
    Joe-Kuo table must give pairwise-distinct columns through dim 16 with
    non-degenerate 2D projections, and dims past the table must raise."""
    from repro.core.lds import SOBOL_MAX_DIMS

    n = 256
    p = sobol(n, 16)
    assert p.shape == (n, 16)
    for i in range(16):
        for j in range(i + 1, 16):
            assert not np.array_equal(p[:, i], p[:, j]), (i, j)
            # non-degenerate 2D projection: recycled columns collapsed the
            # pair onto exactly the 16 diagonal cells of a 16x16 grid;
            # genuine Sobol pairs here occupy >= 64 cells (some unscrambled
            # high-dim pairs do sit at that coarse-resolution floor)
            cells = (np.floor(p[:, i] * 16).astype(int),
                     np.floor(p[:, j] * 16).astype(int))
            grid = np.zeros((16, 16), int)
            np.add.at(grid, cells, 1)
            assert np.count_nonzero(grid) >= 64, (i, j, np.count_nonzero(grid))
    # each column is still a (0,1)-sequence in base 2
    for i in range(16):
        assert star_discrepancy_1d(p[:, i]) < 0.02, i
    with pytest.raises(ValueError):
        sobol(8, SOBOL_MAX_DIMS + 1)
    assert sobol(8, SOBOL_MAX_DIMS).shape == (8, SOBOL_MAX_DIMS)


def test_radical_inverse_exact_float32():
    i = np.arange(1024, dtype=np.uint32)
    x = radical_inverse_base2(i)
    assert np.all((x >= 0) & (x < 1))
    assert np.all(np.float32(x).astype(np.float64) == x)  # exactly representable
    assert len(np.unique(np.float32(x))) == 1024


# ------------------------------------------------------ stochastic routing


def test_moe_sampled_routing_marginals():
    """router_noise: expert choice ~ gate distribution via the monotone
    inverse (the paper's mapping inside the MoE layer)."""
    from repro.models.moe import _route

    rng = np.random.default_rng(0)
    T, E, k = 2048, 8, 2
    logits = rng.normal(0, 1.5, (1, T, E))
    gates = jnp.asarray(
        np.exp(logits) / np.exp(logits).sum(-1, keepdims=True), jnp.float32
    )
    xi = jnp.asarray(rng.random((1, T, k)), jnp.float32)
    ids, w = _route(gates, k, xi)
    ids = np.asarray(ids).reshape(-1)
    counts = np.bincount(ids, minlength=E) / ids.size
    expect = np.asarray(gates).mean(axis=(0, 1))
    np.testing.assert_allclose(counts, expect, atol=0.03)
    assert np.all(np.asarray(w) >= 0)


def test_moe_topk_routing_is_default():
    from repro.models.moe import _route

    gates = jnp.asarray([[0.1, 0.6, 0.3], [0.5, 0.2, 0.3]], jnp.float32)
    ids, w = _route(gates, 2, None)
    assert np.array_equal(np.asarray(ids), [[1, 2], [0, 2]])
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, rtol=1e-6)


# ----------------------------------------------------------- k-ary collapse


def test_kary_collapse_counts():
    """Paper §5: 'a higher branching factor simply results by collapsing two
    (or more) levels' — a 4-ary traversal visits ceil(depth/2) nodes. We
    verify the counting model: 4-ary loads == ceil(binary_visits / 2)."""
    from repro.core import (
        build_forest,
        np_sample_forest_counting,
    )

    rng = np.random.default_rng(1)
    w = normalize_weights(rng.random(512) ** 10 + 1e-12)
    f = build_forest(jnp.asarray(w), 128)
    xi = rng.random(4096).astype(np.float32)
    idx, loads = np_sample_forest_counting(f, xi)
    tree_visits = loads - 1  # minus the guide-table load
    kary_loads = 1 + np.ceil(tree_visits / 2)
    assert np.all(kary_loads <= loads)
    assert float(kary_loads.mean()) < float(loads.mean()) or tree_visits.max() <= 1


# ------------------------------------------------- parallel alias building


def _alias_mass(q: np.ndarray, alias: np.ndarray) -> np.ndarray:
    """Mass each item ends up with: own cell q_i + sum of (1-q_c) over cells
    aliasing it. Valid table <=> mass == n*p (exactly, in float64)."""
    n = len(q)
    mass = q.astype(np.float64).copy()
    np.add.at(mass, alias, 1.0 - q.astype(np.float64))
    return mass


@settings
@hypothesis.given(
    w=st.lists(
        st.floats(min_value=1e-6, max_value=1e6, allow_nan=False),
        min_size=1, max_size=400,
    ),
)
def test_parallel_alias_is_valid(w):
    from repro.core.alias import build_alias, build_alias_parallel

    w = np.asarray(w, np.float64)
    t = build_alias_parallel(w)
    q, alias = np.asarray(t.q, np.float64), np.asarray(t.alias)
    n = len(w)
    assert np.all((q >= -1e-6) & (q <= 1 + 1e-6))
    mass = _alias_mass(q, alias)
    np.testing.assert_allclose(mass, w / w.sum() * n, rtol=1e-4, atol=1e-4)
    # Vose reference obeys the same equation (sanity of the checker)
    tv = build_alias(w)
    mv = _alias_mass(np.asarray(tv.q, np.float64), np.asarray(tv.alias))
    np.testing.assert_allclose(mv, w / w.sum() * n, rtol=1e-4, atol=1e-4)


def test_parallel_alias_sampling_marginals():
    from repro.core.alias import build_alias_parallel, np_sample_alias

    rng = np.random.default_rng(0)
    w = normalize_weights(rng.random(64) ** 6 + 1e-6)
    t = build_alias_parallel(w)
    xi = rng.random(1 << 16)
    idx = np_sample_alias(np.asarray(t.q, np.float64), np.asarray(t.alias), xi)
    counts = np.bincount(idx, minlength=64)
    expect = w * len(xi)
    chi2 = np.sum((counts - expect) ** 2 / np.maximum(expect, 1e-9))
    assert chi2 < 220, chi2  # 63 dof


def test_parallel_alias_dyadic_boundary_regression():
    """Exact dyadic weights make a heavy's supply end coincide with a light's
    demand boundary; the pre-fix build charged debt to a zero-surplus heavy
    (``npi == 1`` exactly) and broke the telescoping-mass invariant by a full
    0.5. The fixed build gates debt on ``surplus > 0`` and routes it past
    zero-surplus runs — mass must be EXACT here (all values dyadic)."""
    from repro.core.alias import build_alias_parallel

    for w in (
        np.array([0.25, 0.25, 0.5, 1.0]),          # npi = (.5, .5, 1, 2)
        np.array([1.0, 0.5, 0.25, 0.25]),          # heavy-first ordering
        np.array([1, 1, 2, 4, 8], np.float64),     # pow2 ladder, sum 16
        np.array([0.5, 1.0, 0.5, 1.0, 1.0]),       # zero-surplus run
        np.array([2, 1, 1, 2, 1, 1], np.float64),  # npi hits 1 twice
    ):
        t = build_alias_parallel(w)
        mass = _alias_mass(np.asarray(t.q, np.float64), np.asarray(t.alias))
        npi = w / w.sum() * len(w)
        # f32 cast of dyadic values in [0,1] is exact => zero tolerance
        assert np.array_equal(mass, npi), (w, mass, npi)


@settings
@hypothesis.given(
    ints=st.lists(st.integers(min_value=1, max_value=64),
                  min_size=2, max_size=12),
)
def test_parallel_alias_dyadic_family_exact(ints):
    """The dyadic/exact-boundary family: integer weights completed to a
    power-of-two total, so every ``npi = w*n/total`` is exactly
    representable and boundary coincidences (including ``npi == 1``
    zero-surplus heavies) occur constantly. The telescoping-mass invariant
    must hold to float64 exactness (f32 table cast is exact for dyadics
    with <= 24 mantissa bits, which these are)."""
    from repro.core.alias import build_alias_parallel

    s = sum(ints)
    total = 1
    while total < s + 1:
        total <<= 1
    w = np.asarray(ints + [total - s], np.float64)  # sum == total (pow2)
    t = build_alias_parallel(w)
    q, alias = np.asarray(t.q, np.float64), np.asarray(t.alias)
    assert np.all((q >= 0.0) & (q <= 1.0))
    mass = _alias_mass(q, alias)
    np.testing.assert_allclose(mass, w / w.sum() * len(w), rtol=0, atol=1e-9)
