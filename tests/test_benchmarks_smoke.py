"""Benchmark harness smoke tests: every module runs end-to-end at tiny
scale and reports sane values (deliverable-d wiring check)."""
import numpy as np


def test_table1_runs_and_beats_binary_on_hdr():
    # paper-scale n: the forest win needs enough periods per guide cell
    # (at n=128 the mod-64 distribution has only 2 periods and ties)
    from benchmarks.table1 import run

    rows = run(n=256, m=256, n_samples=1 << 13)
    by = {(name, method): r for name, method, r in rows}
    f = by[("(i mod 64 + 1)^35", "cutpoint+radix_forest")]["average_32"]
    b = by[("(i mod 64 + 1)^35", "cutpoint+binary")]["average_32"]
    assert f < b


def test_convergence_inverse_beats_alias():
    from benchmarks.convergence import run_1d, run_discrepancy

    rows = run_1d(max_log2=12)
    assert all(e_ali > e_inv for _, e_inv, e_ali in rows[-2:])
    d = run_discrepancy(1024)
    assert d["alias"] > 5 * d["inverse"]
    assert abs(d["inverse"] - d["input"]) < 1e-6  # monotone warp preserves


def test_convergence_2d_uses_multirow_forest():
    from benchmarks.convergence import run_2d

    rows = run_2d(max_log2=12, h=16, w=32)
    assert all(np.isfinite(e) for _, e, _ in rows)


def test_construction_bench_runs():
    from benchmarks.construction import run

    rows = run(sizes=(1 << 10,))
    assert rows[0]["forest_us"] > 0 and rows[0]["alias_us"] > 0


def test_throughput_bench_runs():
    from benchmarks.sampling_throughput import run

    rows = run(n=1 << 10, batch=1 << 12)
    names = {r[0] for r in rows}
    assert {"binary_search", "forest_alg2", "alias"} <= names


def test_construction_sharded_bench_runs():
    from benchmarks.construction import run_sharded

    rows = run_sharded(sizes=(1 << 10,))
    assert rows and all(r["us"] > 0 for r in rows)
    assert rows[0]["devices"] == 1  # sweep always includes the 1-shard row
    # windowed per-device work columns
    assert all(0 < r["window"] <= 1 << 10 for r in rows)
    assert all(0 < r["util"] <= 1.0 for r in rows)


def test_construction_delta_bench_runs():
    from benchmarks.construction import run_delta

    rows = run_delta(sizes=(1 << 10,))
    kinds = {r["kind"] for r in rows}
    assert kinds == {"noop", "sparse", "full"}
    by = {r["kind"]: r for r in rows}
    assert by["noop"]["dirty_shards"] == 0 and by["noop"]["dirty_chunks"] == 0
    assert by["sparse"]["dirty_chunks"] == 1
    # the sparse-costs-more-than-full bug, pinned structurally: sparse
    # rebuilds only the dirty windows, full rebuilds all of them
    D = rows[0]["devices"]
    assert by["noop"]["rebuilt_windows"] == 0
    assert by["full"]["rebuilt_windows"] == D
    if D > 1:
        assert by["sparse"]["rebuilt_windows"] < D
    assert all(r["update_us"] > 0 and r["full_us"] > 0 for r in rows)


def test_pool_construction_bench_runs():
    from benchmarks.pool import run_construction

    rows = run_construction(batches=(4,), n=256)
    assert rows and rows[0]["B"] == 4
    assert rows[0]["batched_us"] > 0 and rows[0]["loop_us"] > 0


def test_pool_sampling_bench_runs():
    from benchmarks.pool import run_sampling

    rows = run_sampling(tenants=8, draws=1 << 10)
    assert {r["path"] for r in rows} == {"pool_ref", "pool_pallas"}
    assert all(r["us"] > 0 and r["classes"] >= 1 for r in rows)


def test_pool_guard_bench_runs():
    from benchmarks.pool import run_sampling_guard

    rows = run_sampling_guard(tenants=8, draws=1 << 10)
    assert {r["guard"] for r in rows} == {"on", "off"}
    assert all(r["us"] > 0 for r in rows)


def test_pool_snapshot_bench_runs():
    from benchmarks.pool import run_snapshot

    rows = run_snapshot(tenant_counts=(8,))
    assert rows[0]["tenants"] == 8
    assert all(rows[0][k] > 0
               for k in ("snapshot_us", "save_us", "restore_us"))


def test_throughput_sharded_bench_runs():
    from benchmarks.sampling_throughput import run_sharded

    rows = run_sharded(n=1 << 10, batch=1 << 12)
    names = {r["name"] for r in rows}
    assert any(n.startswith("forest_sharded_d") for n in names)
    # both paths per device count: the masked-psum oracle row and the
    # owner-routed drain row with its static bucket capacity
    routed = [r for r in rows if r["name"].startswith("forest_sharded_routed")]
    assert routed and len(routed) * 2 == len(rows)
    assert all(0 < r["bucket"] <= 1 << 12 for r in routed)
    assert all(0 < r["window"] <= 1 << 10 for r in rows)


def test_bench_regression_key_extraction():
    """The CI structure gate: numeric values are stripped, labels and
    non-numeric values are kept, and the comparator flags missing/renamed
    keys but tolerates value drift and extra rows."""
    from benchmarks.check_regression import compare, line_key

    assert (
        line_key("construction,n=4096,forest_us=7628,forest_Mentries_s=0.54")
        == "construction,n=4096,forest_us,forest_Mentries_s"
    )
    assert (
        line_key("table1,i^20,cutpoint+binary,max=9,avg=1.23 | paper: max=8")
        == "table1,i^20,cutpoint+binary,max,avg"
    )
    assert line_key("construction_sharded,n=65536,devices=8,forest_us=12") == (
        "construction_sharded,n=65536,devices=8,forest_us"
    )

    base = {"sections": {"S": {"lines": ["a,n=1,x=2", "a,n=9,x=3", "b,y=1"]}}}
    ok = {"sections": {"S": {"lines": ["a,n=1,x=9", "a,n=9,x=0", "b,y=7",
                                       "c,z=1"]}}}
    assert compare(base, ok) == []
    # a sweep coordinate disappearing is a missing row, not value drift
    missing_coord = {"sections": {"S": {"lines": ["a,n=1,x=9", "a,n=1,x=3",
                                                  "b,y=7"]}}}
    assert any("a,n=9,x" in e for e in compare(base, missing_coord))
    renamed = {"sections": {"S": {"lines": ["a,n=1,x2=9", "a,n=9,x2=0",
                                            "b,y=7"]}}}
    assert compare(base, renamed)
    missing_section = {"sections": {}}
    assert any("missing section" in e for e in compare(base, missing_section))


def test_serving_diversity_qmc_wins():
    from benchmarks.serving_diversity import run

    rows = run(vocab=512, n=2048)
    assert rows["inverse_qmc"] < rows["inverse_prng"]
    assert rows["inverse_qmc"] < rows["alias_qmc"]


def test_spatial_bench_runs():
    from benchmarks.spatial import run_construction, run_sampling

    rows = run_construction(shapes=((8, 16),))
    assert rows[0]["bulk_us"] > 0 and rows[0]["loop_us"] > 0
    # structural: one multi-row launch per class + the marginal, never H+1
    assert rows[0]["launches"] < 8 + 1

    rows = run_sampling(shapes=((8, 16),), draws=1 << 10)
    r = rows[0]
    assert r["bulk_us"] > 0 and r["msps"] > 0
    # the one-launch-per-class (never per-distinct-row) witness
    assert r["launches"] <= r["distinct_rows"]
    assert r["launches"] == 1  # single class, unsharded: fused pipeline
