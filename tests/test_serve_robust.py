"""Serving-robustness conformance suite.

The contract under test (``repro.robust`` + its pool/serve/dist hooks):

- **Validated admission** — every malformed weight row is rejected at the
  pool / spatial-map / engine boundary with the structured taxonomy
  (``non_finite`` / ``negative`` / ``zero_total`` / ``overflow_on_pad`` /
  ``bad_dtype`` / ``bad_shape``), or repaired/flagged under the lenient
  ``clamp`` / ``quarantine`` policies — never admitted silently and never
  surfaced as a mid-drain crash.
- **Isolation** — an adversarial tenant can never corrupt a co-tenant:
  after any fault the co-tenant's drains stay **bit-identical** to a pool
  that never saw the bad input, and ``verify_pool`` stays clean.
- **Snapshot/restore** — ``save_serving``/``load_serving`` round-trips the
  pool arenas, all four QMC stream classes, and the engine's slot state;
  a killed process (``os._exit``, subprocess matrix below) resumes with
  bit-identical subsequent drains and stream counters.
- **Degraded mode** — a sharded forest sampled on a shrunk mesh with
  ``on_mismatch="degrade"`` falls back to gathered single-device descent,
  elementwise-identical, with ``degraded=True`` in its stats.

The fuzz lane runs under real Hypothesis when installed, else the
deterministic stub in ``tests/_stubs`` (same keyword-strategy API).
"""
import os
import subprocess
import sys
import textwrap

import hypothesis
import hypothesis.strategies as st
import numpy as np
import jax.numpy as jnp
import pytest

from repro.ckpt import latest_step, load_state, save_state
from repro.pool import ForestPool, Handle
from repro.robust import (
    NegativeWeightError,
    NonFiniteWeightError,
    OverflowOnPadError,
    QuarantinedError,
    RequestError,
    ServingError,
    StaleHandleError,
    WeightDtypeError,
    WeightShapeError,
    ZeroTotalError,
    load_serving,
    save_serving,
    verify_pool,
)
from repro.serve import (
    DeviceQmc2Streams,
    DeviceQmcStreams,
    PooledForestSampler,
    Qmc2Streams,
    QmcStreams,
    Request,
    ServeEngine,
)
from repro.serve.sampler import restore_streams

_ENV = dict(os.environ, PYTHONPATH="src")

_BAD = {
    "nan": lambda n: np.where(np.arange(n) == n // 2, np.nan, 1.0),
    "inf": lambda n: np.where(np.arange(n) == 0, np.inf, 1.0),
    "neg": lambda n: np.where(np.arange(n) == n - 1, -1.0, 2.0),
    "zero": lambda n: np.zeros(n),
}


# ------------------------------------------------------------ admission


def test_admission_taxonomy_pool_insert():
    """Every violation class raises its structured error (all ValueError
    subclasses) from insert, under the default reject policy, and the
    rejected row leaves no trace in the pool."""
    pool = ForestPool()
    cases = [
        (_BAD["nan"](6), NonFiniteWeightError, "non_finite"),
        (_BAD["inf"](6), NonFiniteWeightError, "non_finite"),
        (_BAD["neg"](6), NegativeWeightError, "negative"),
        (_BAD["zero"](6), ZeroTotalError, "zero_total"),
        (np.full(4, 1e308), OverflowOnPadError, "overflow_on_pad"),
        (np.ones((2, 3)), WeightShapeError, "bad_shape"),
        (np.ones(0), WeightShapeError, "bad_shape"),
        (np.asarray(["a", "b"]), WeightDtypeError, "bad_dtype"),
    ]
    for method in ("forest", "alias"):
        for w, err, code in cases:
            with pytest.raises(err) as ei:
                pool.insert(w, method=method)
            assert ei.value.code == code
            assert isinstance(ei.value, ValueError)
    assert pool.stats()["tenants"] == 0
    assert verify_pool(pool) == []


def test_admission_taxonomy_pool_update_leaves_state_untouched():
    """A rejected update (direct or via delta) must leave the tenant
    serving exactly its previous distribution."""
    rng = np.random.default_rng(0)
    pool = ForestPool()
    h = pool.insert(rng.random(9) + 1e-3)
    before = pool.weights(h).copy()
    xi = rng.random(16).astype(np.float32)
    drains = pool.sample([h] * 16, xi)
    for w, err in [
        (_BAD["nan"](9), NonFiniteWeightError),
        (_BAD["neg"](9), NegativeWeightError),
        (_BAD["zero"](9), ZeroTotalError),
    ]:
        with pytest.raises(err):
            pool.update_weights(h, w)
    # a delta that drives an entry negative is the same violation
    with pytest.raises(NegativeWeightError):
        pool.update_weights(h, delta=-10.0 * np.ones(9))
    np.testing.assert_array_equal(pool.weights(h), before)
    np.testing.assert_array_equal(pool.sample([h] * 16, xi), drains)
    assert verify_pool(pool) == []


def test_negative_entries_with_positive_sum_regression():
    """Regression (pre-taxonomy bug): a row like [2, -1, 2] has a positive
    total, so it used to sail through the admission check and build a
    clipped/cummaxed CDF silently biased toward index 0. It must now be a
    structured ``negative`` rejection at EVERY admission surface."""
    bad = np.asarray([2.0, -1.0, 2.0])
    pool = ForestPool()
    for method in ("forest", "alias"):
        with pytest.raises(NegativeWeightError):
            pool.insert(bad, method=method)
    h = pool.insert(np.ones(3))
    with pytest.raises(NegativeWeightError):
        pool.update_weights(h, bad)

    from repro.spatial import Map2DSampler

    with pytest.raises(NegativeWeightError):
        Map2DSampler(np.stack([bad, np.ones(3)]))
    m = Map2DSampler(np.ones((2, 3)))
    with pytest.raises(NegativeWeightError):
        m.update_map({0: bad})

    eng = ServeEngine(None, None, n_slots=2)
    with pytest.raises(RequestError, match="negative"):
        eng.submit(Request(rid=0, prompt=np.zeros(0, np.int32), prior=bad))


def test_clamp_policy_repairs():
    """clamp admits every value-violation by repair: NaN -> 0, +Inf ->
    f32max, negatives -> 0, all-zero -> uniform; the repaired row is what
    the tenant serves."""
    pool = ForestPool(policy="clamp")
    h = pool.insert(np.asarray([1.0, np.nan, 1.0]))
    w = pool.weights(h)
    assert np.isfinite(w).all() and w[1] == 0.0 and abs(w.sum() - 1.0) < 1e-6
    h2 = pool.insert(np.asarray([2.0, -5.0, 2.0]))
    assert pool.weights(h2)[1] == 0.0
    h3 = pool.insert(np.zeros(4))
    np.testing.assert_allclose(pool.weights(h3), np.full(4, 0.25), rtol=1e-6)
    h4 = pool.insert(np.asarray([np.inf, 1.0]))
    assert np.isfinite(pool.weights(h4)).all()
    out = pool.sample([h, h2, h3, h4], np.asarray([0.1, 0.5, 0.9, 0.3], np.float32))
    assert ((out >= 0) & (out < 4)).all()
    # structural violations are never repaired, under any policy
    with pytest.raises(WeightShapeError):
        pool.insert(np.ones((2, 2)))
    assert verify_pool(pool) == []


def test_quarantine_policy_flags_and_clears():
    pool = ForestPool(policy="quarantine")
    good = pool.insert(np.asarray([3.0, 1.0]))
    bad = pool.insert(_BAD["nan"](5))
    assert pool.is_quarantined(bad) and not pool.is_quarantined(good)
    assert pool.stats()["quarantined"] == 1
    with pytest.raises(QuarantinedError):
        pool.weights(bad)
    # the placeholder still drains, in-range (serving never crashes)
    out = pool.sample([bad] * 8, np.linspace(0, 0.99, 8).astype(np.float32))
    assert ((out >= 0) & (out < 5)).all()
    # a clean update clears the flag and serves the new row
    pool.update_weights(bad, np.arange(1.0, 6.0))
    assert not pool.is_quarantined(bad)
    np.testing.assert_allclose(pool.weights(bad),
                               np.arange(1.0, 6.0, dtype=np.float32) / 15.0,
                               rtol=1e-6)
    # a bad update re-quarantines; evict drops the flag
    pool.update_weights(bad, _BAD["zero"](5))
    assert pool.is_quarantined(bad) and pool.stats()["quarantined"] == 1
    pool.evict(bad)
    assert pool.stats()["quarantined"] == 0
    assert verify_pool(pool) == []


def test_stale_handle_is_structured():
    pool = ForestPool()
    h = pool.insert(np.ones(4))
    pool.evict(h)
    for op in (
        lambda: pool.sample([h], np.asarray([0.5], np.float32)),
        lambda: pool.update_weights(h, np.ones(4)),
        lambda: pool.weights(h),
        lambda: pool.evict(h),
    ):
        with pytest.raises(StaleHandleError) as ei:
            op()
        assert ei.value.code == "stale_handle"


def test_guard_detects_corrupted_arena_rows():
    """guard=True cross-checks each touched group's invariants before the
    launch: a payload corrupted behind the pool's back (bit-flip, bad
    restore) fails loudly instead of sampling garbage."""
    pool = ForestPool()
    hf = pool.insert(np.arange(1.0, 9.0), method="forest")
    ha = pool.insert(np.arange(1.0, 9.0), method="alias")
    xi = np.asarray([0.3, 0.7], np.float32)
    out = pool.sample([hf, ha], xi, guard=True)  # clean pool passes
    assert ((out >= 0) & (out < 8)).all()
    sc = pool.classes[hf.size_class]
    sc.forest = sc.forest._replace(
        cdf=sc.forest.cdf.at[hf.row, 3].set(jnp.nan)
    )
    with pytest.raises(ValueError, match="guard: corrupted"):
        pool.sample([hf], np.asarray([0.5], np.float32), guard=True)
    ar = pool.alias_classes[ha.size_class]
    ar.table = ar.table._replace(q=ar.table.q.at[ha.row, 0].set(2.0))
    with pytest.raises(ValueError, match="guard: corrupted"):
        pool.sample([ha], np.asarray([0.5], np.float32), guard=True)


# ----------------------------------------------------- engine admission


def test_engine_submit_validation():
    eng = ServeEngine(None, None, n_slots=2)
    z = np.zeros(0, np.int32)
    with pytest.raises(RequestError):
        eng.submit(Request(rid=0, prompt=z, prior=np.ones(4),
                           prior2d=np.ones((2, 3))))
    with pytest.raises(RequestError):  # no model, no prior
        eng.submit(Request(rid=1, prompt=z))
    with pytest.raises(RequestError, match="bad_dtype"):
        eng.submit(Request(rid=2, prompt=z, prior=np.asarray(["x", "y"])))
    with pytest.raises(RequestError, match="non_finite"):
        eng.submit(Request(rid=3, prompt=z, prior=_BAD["nan"](6)))
    with pytest.raises(RequestError, match="bad_shape"):
        eng.submit(Request(rid=4, prompt=z, prior2d=[]))
    with pytest.raises(RequestError, match="non_finite"):
        eng.submit(Request(rid=5, prompt=z, prior2d=_BAD["inf"](6).reshape(2, 3)))
    assert len(eng.queue) == 0
    # lenient prior pool => value violations defer to admit-time repair
    lenient = ServeEngine(
        None, None, n_slots=2,
        prior_sampler=PooledForestSampler(n_slots=2, policy="clamp"),
    )
    r = Request(rid=6, prompt=z, prior=_BAD["nan"](6), max_new=3)
    lenient.submit(r)
    lenient.run(max_steps=20)
    assert r.done and r.error is None and len(r.out) == 3
    # structural violations stay submit-time rejections even when lenient
    with pytest.raises(RequestError, match="bad_shape"):
        lenient.submit(Request(rid=7, prompt=z, prior=np.ones((2, 2))))


def test_engine_retire_isolates_per_request_faults():
    """on_fault="retire": a fault scoped to one request retires that
    request with a structured ``error`` result; co-tenant slots keep
    serving and finish normally."""
    rng = np.random.default_rng(5)
    eng = ServeEngine(None, None, n_slots=3, on_fault="retire")
    reqs = [
        Request(rid=i, prompt=np.zeros(0, np.int32), max_new=6,
                prior=rng.random(10) + 1e-3)
        for i in range(3)
    ]
    for r in reqs:
        eng.submit(r)
    eng.step()  # admit everyone
    victim_slot, victim_handle = next(iter(eng.prior_handles.items()))
    victim = eng.slots[victim_slot]
    eng.prior_sampler.pool.evict(victim_handle)  # corruption: handle dies
    eng.run(max_steps=40)
    assert victim.done and victim.error is not None
    assert victim.error.startswith("stale_handle")
    for r in reqs:
        if r is victim:
            continue
        assert r.done and r.error is None and len(r.out) == 6
    assert verify_pool(eng.prior_sampler.pool) == []


def test_engine_retire_isolates_mismatched_map():
    """Same-shape different-content prior2d passes submit (content is only
    checkable against the admitted shared map); under retire it fails at
    admit as a per-request error while the matching request serves."""
    img = np.random.default_rng(0).random((4, 8)) + 1e-3
    other = img.copy()
    other[0, 0] += 1.0
    eng = ServeEngine(None, None, n_slots=2, on_fault="retire")
    a = Request(rid=0, prompt=np.zeros(0, np.int32), prior2d=img, max_new=4)
    b = Request(rid=1, prompt=np.zeros(0, np.int32), prior2d=other, max_new=4)
    eng.submit(a)
    eng.submit(b)
    eng.run(max_steps=30)
    assert a.done and a.error is None and len(a.out) == 4
    assert b.done and b.error is not None


# ---------------------------------------------------- co-tenant isolation


def test_cotenant_drains_bit_identical_after_faults():
    """Twin-pool oracle, by hand: a chaos pool absorbs a stream of faults
    under quarantine; its co-tenants' drains must stay bit-identical to a
    clean pool that never saw any of it."""
    rng = np.random.default_rng(11)
    weights = [rng.random(n) + 1e-3 for n in (5, 12, 30)]
    methods = ["forest", "alias", "forest"]
    chaos = ForestPool(policy="quarantine")
    clean = ForestPool(policy="quarantine")
    ch = chaos.insert_many(weights, method=methods)
    cl = clean.insert_many(weights, method=methods)
    for flavor in ("nan", "inf", "neg", "zero"):
        chaos.insert(_BAD[flavor](7))           # quarantined placeholder
        tmp = chaos.insert(rng.random(6) + 1e-3)
        chaos.evict(tmp)
        with pytest.raises(StaleHandleError):
            chaos.sample([tmp], np.asarray([0.5], np.float32))
        xi = rng.random(9).astype(np.float32)
        got = chaos.sample([ch[i % 3] for i in range(9)], xi)
        want = clean.sample([cl[i % 3] for i in range(9)], xi)
        np.testing.assert_array_equal(got, want)
        assert verify_pool(chaos) == []


def test_chaos_harness_contract():
    from repro.robust.faults import FaultPlan, run_chaos

    plan = FaultPlan.default(steps=16, seed=2)
    assert plan.faults  # the schedule actually injects something
    for policy in ("quarantine", "reject"):
        report = run_chaos(plan, steps=16, policy=policy, seed=2)
        assert report["drains_equal"], policy
        assert report["verify_errors"] == [], policy
        assert report["injected"] == len(plan.faults)
    # under reject every weight fault must surface as a structured code
    report = run_chaos(plan, steps=16, policy="reject", seed=2)
    weight_faults = [c for c in report["caught"]
                     if c[1] in ("bad_insert", "bad_update")]
    assert weight_faults
    for _, _, code in weight_faults:
        assert code in ("non_finite", "negative", "zero_total",
                        "overflow_on_pad")


# ------------------------------------------------------ snapshot/restore


def test_stream_snapshot_restore_all_kinds():
    """All four stream classes: restore() is exact — subsequent draws and
    counters are bit-identical to the uninterrupted original."""
    slots = np.asarray([0, 2, 2, 5, 0])
    for cls in (QmcStreams, DeviceQmcStreams):
        s = cls(8, seed=7)
        s.next(slots)
        twin = restore_streams(s.snapshot())
        assert type(twin) is cls
        for _ in range(3):
            a, b = s.next(slots), twin.next(slots)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(s.counters),
                                      np.asarray(twin.counters))
    for cls in (Qmc2Streams, DeviceQmc2Streams):
        s = cls(8, seed=7)
        s.next(slots)
        twin = restore_streams(s.snapshot())
        assert type(twin) is cls
        for _ in range(3):
            (u1, v1), (u2, v2) = s.next(slots), twin.next(slots)
            np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
            np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(s.counters),
                                      np.asarray(twin.counters))


def _churn(pool, streams, hs, step, outs):
    """One deterministic churn step: update a tenant, churn the last slot,
    drain every live tenant through the slot streams."""
    rng = np.random.default_rng(1000 + step)
    t = int(rng.integers(len(hs)))
    if hs[t] is not None:
        pool.update_weights(hs[t], rng.random(hs[t].n) + 1e-3)
    if step % 5 == 2 and hs[-1] is not None:
        pool.evict(hs[-1])
        hs[-1] = None
    if step % 5 == 4 and hs[-1] is None:
        hs[-1] = pool.insert(rng.random(7) + 1e-3)
    live = [h for h in hs if h is not None]
    slots = np.arange(2 * len(live)) % streams.n_slots
    handles = [live[i % len(live)] for i in range(len(slots))]
    outs.append(pool.sample_streams(handles, slots, streams))


def _fresh_serving():
    pool = ForestPool(policy="quarantine")
    streams = DeviceQmcStreams(8, seed=3)
    rng = np.random.default_rng(0)
    hs = pool.insert_many(
        [rng.random(n) + 1e-3 for n in (5, 9, 17, 33, 12, 7)],
        method=["forest", "alias", "forest", "alias", "forest", "forest"],
    )
    pool.insert(_BAD["nan"](4))  # a quarantined tenant rides along
    return pool, streams, hs


def test_pool_snapshot_restore_bitwise_midchurn(tmp_path):
    """Mid-churn snapshot through save_serving/load_serving: the restored
    pool + streams replay the remaining schedule bit-identically to the
    uninterrupted run (drains AND device counters), and the quarantine
    set survives the round trip."""
    K, N = 6, 14
    pool, streams, hs = _fresh_serving()
    ref = []
    for step in range(N):
        _churn(pool, streams, hs, step, ref)

    pool, streams, hs = _fresh_serving()
    outs = []
    for step in range(K):
        _churn(pool, streams, hs, step, outs)
    save_serving(tmp_path, K, pool=pool, streams=streams,
                 extra=dict(hs=[None if h is None else tuple(h) for h in hs]))
    del pool, streams, hs

    states, step = load_serving(tmp_path)
    assert step == K
    pool = ForestPool.restore(states["pool"])
    streams = restore_streams(states["streams"])
    hs = [None if h is None else Handle(h[0], h[1], h[2], h[3], h[4])
          for h in states["extra"]["hs"]]
    assert verify_pool(pool) == []
    assert pool.stats()["quarantined"] == 1
    for step in range(K, N):
        _churn(pool, streams, hs, step, outs)
    assert len(outs) == len(ref)
    for a, b in zip(outs, ref):
        np.testing.assert_array_equal(a, b)


def test_engine_snapshot_restore_continuation(tmp_path):
    """A prior-serving engine snapshotted mid-flight (live slots AND a
    still-queued request) resumes through the file round-trip with
    identical subsequent outputs."""
    rng = np.random.default_rng(9)
    eng = ServeEngine(None, None, n_slots=2, on_fault="retire")
    reqs = [
        Request(rid=i, prompt=np.zeros(0, np.int32), max_new=8,
                prior=rng.random(6 + i) + 1e-3)
        for i in range(4)
    ]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    save_serving(tmp_path, eng.steps, engine=eng)
    states, _ = load_serving(tmp_path)
    twin = ServeEngine.restore(states["engine"])
    # restored Request objects are copies: grab them before stepping
    twin_reqs = {r.rid: r for r in
                 [s for s in twin.slots if s is not None] + list(twin.queue)}
    assert set(twin_reqs) == {r.rid for r in reqs if not r.done}
    for _ in range(40):
        eng.step()
        twin.step()
        if all(r.done for r in reqs) and all(r.done for r in twin_reqs.values()):
            break
    live = {r.rid: r for r in reqs}
    for rid, r in twin_reqs.items():
        assert r.done and r.error is None
        assert live[rid].done and live[rid].error is None
        # tokens emitted before the snapshot live only in the original's
        # out list; everything from the snapshot on must match exactly
        k = len(live[rid].out) - len(r.out)
        assert 0 <= k
        np.testing.assert_array_equal(r.out, live[rid].out[k:])


def test_save_state_codec_roundtrip(tmp_path):
    """The tagged-JSON state codec: arrays (dtype-exact), tuples, sets,
    int-keyed dicts, None, bools, big ints all round-trip; state blobs and
    pytree checkpoints refuse to masquerade as each other."""
    blob = dict(
        a=np.arange(5, dtype=np.uint32),
        b=np.asarray([1.5, np.pi], np.float32),
        t=(1, "x", (2.5, None)),
        s={("forest", 8, 0, 1), ("alias", 16, 2, 3)},
        d={0: "zero", 7: np.ones(2), "k": True},
        n=None,
        big=2**80,
    )
    save_state(tmp_path, blob, 3)
    save_state(tmp_path, blob, 5)
    assert latest_step(tmp_path) == 5
    got, step = load_state(tmp_path)
    assert step == 5
    np.testing.assert_array_equal(got["a"], blob["a"])
    assert got["a"].dtype == np.uint32
    np.testing.assert_array_equal(got["b"], blob["b"])
    assert got["b"].dtype == np.float32
    assert got["t"] == blob["t"] and isinstance(got["t"], tuple)
    assert got["s"] == blob["s"] and isinstance(got["s"], set)
    assert set(got["d"]) == {0, 7, "k"} and got["d"][0] == "zero"
    np.testing.assert_array_equal(got["d"][7], np.ones(2))
    assert got["n"] is None and got["big"] == 2**80

    from repro.ckpt import save

    save(tmp_path / "tree", {"w": jnp.ones(3)}, 1)
    with pytest.raises(ValueError, match="pytree checkpoint"):
        load_state(tmp_path / "tree")


# ------------------------------------------------------------- fuzz lane


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    n=st.integers(min_value=1, max_value=33),
    flavor=st.sampled_from(["nan", "inf", "neg", "zero", "denormal", "good"]),
    policy=st.sampled_from(["reject", "clamp", "quarantine"]),
    method=st.sampled_from(["forest", "alias"]),
    scale=st.floats(min_value=1e-30, max_value=1e30),
)
def test_fuzz_admission_never_crashes_or_corrupts(n, flavor, policy, method,
                                                  scale):
    """Property: for ANY weight row, admission either returns a live
    handle or raises a structured ServingError; the co-tenant's drains are
    bit-identical to a pool that never saw the row; verify_pool is clean."""
    rng = np.random.default_rng(n * 7 + len(flavor))
    base = rng.random(9) + 1e-3
    pool = ForestPool(policy=policy)
    clean = ForestPool(policy=policy)
    h = pool.insert(base)
    hc = clean.insert(base)
    xi = rng.random(8).astype(np.float32)
    if flavor == "good":
        w = (rng.random(n) + 1e-3) * scale
    elif flavor == "denormal":
        w = np.full(n, 5e-324)
    else:
        w = _BAD[flavor](n) * scale
    try:
        hb = pool.insert(w, method=method)
        out = pool.sample([hb], np.asarray([0.5], np.float32))
        assert 0 <= out[0] < n
    except ServingError:
        pass
    np.testing.assert_array_equal(pool.sample([h] * 8, xi),
                                  clean.sample([hc] * 8, xi))
    assert verify_pool(pool) == []


# ------------------------------------------------------- degraded mode


def test_sample_sharded_stats_and_mismatch_validation():
    import jax
    from jax.sharding import Mesh

    from repro.dist import forest as DF

    mesh = Mesh(np.asarray(jax.devices()).reshape(-1), ("data",))
    w = jnp.asarray(np.random.default_rng(0).random(32), jnp.float32)
    sf = DF.build_forest_sharded(w, 8, mesh=mesh)
    xi = jnp.asarray(np.random.default_rng(1).random(16), jnp.float32)
    plain = np.asarray(DF.sample_sharded(sf, xi, mesh=mesh))
    got, stats = DF.sample_sharded(sf, xi, mesh=mesh, with_stats=True)
    np.testing.assert_array_equal(np.asarray(got), plain)
    assert stats["degraded"] is False
    with pytest.raises(ValueError):
        DF.sample_sharded(sf, xi, mesh=mesh, on_mismatch="bogus")


# --------------------------------------------------- subprocess matrices


_KILL_RESUME_SCRIPT = r"""
import os, sys
import numpy as np
from repro.pool import ForestPool, Handle
from repro.robust import load_serving, save_serving, verify_pool
from repro.serve.sampler import DeviceQmcStreams, restore_streams

MODE, DIR = sys.argv[1], sys.argv[2]
K, N = 6, 14
BAD = np.where(np.arange(4) == 2, np.nan, 1.0)

def fresh():
    pool = ForestPool(policy="quarantine")
    streams = DeviceQmcStreams(8, seed=3)
    rng = np.random.default_rng(0)
    hs = pool.insert_many(
        [rng.random(n) + 1e-3 for n in (5, 9, 17, 33, 12, 7)],
        method=["forest", "alias", "forest", "alias", "forest", "forest"])
    pool.insert(BAD)
    return pool, streams, hs

def churn(pool, streams, hs, step, outs):
    rng = np.random.default_rng(1000 + step)
    t = int(rng.integers(len(hs)))
    if hs[t] is not None:
        pool.update_weights(hs[t], rng.random(hs[t].n) + 1e-3)
    if step % 5 == 2 and hs[-1] is not None:
        pool.evict(hs[-1]); hs[-1] = None
    if step % 5 == 4 and hs[-1] is None:
        hs[-1] = pool.insert(rng.random(7) + 1e-3)
    live = [h for h in hs if h is not None]
    slots = np.arange(2 * len(live)) % streams.n_slots
    handles = [live[i % len(live)] for i in range(len(slots))]
    outs.append(pool.sample_streams(handles, slots, streams))

outs = []
if MODE == "full":
    pool, streams, hs = fresh()
    for step in range(N):
        churn(pool, streams, hs, step, outs)
    outs = outs[K:]
elif MODE == "part1":
    pool, streams, hs = fresh()
    for step in range(K):
        churn(pool, streams, hs, step, outs)
    save_serving(DIR, K, pool=pool, streams=streams,
                 extra=dict(hs=[None if h is None else tuple(h) for h in hs]))
    os._exit(17)  # kill: no cleanup, no atexit, nothing flushed after save
elif MODE == "part2":
    states, step = load_serving(DIR)
    assert step == K
    pool = ForestPool.restore(states["pool"])
    streams = restore_streams(states["streams"])
    hs = [None if h is None else Handle(h[0], h[1], h[2], h[3], h[4])
          for h in states["extra"]["hs"]]
    assert verify_pool(pool) == []
    assert pool.stats()["quarantined"] == 1
    for step in range(K, N):
        churn(pool, streams, hs, step, outs)

print("COUNTERS", ",".join(str(int(c)) for c in np.asarray(streams.counters)))
for o in outs:
    print("OUT", ",".join(str(int(v)) for v in o))
"""


@pytest.mark.slow
def test_serving_kill_resume_bitwise_subprocess(tmp_path):
    """The kill/resume matrix: a serving process killed with ``os._exit``
    right after ``save_serving`` resumes in a fresh process and produces
    bit-identical drains and final stream counters to a process that was
    never killed."""
    def run(mode, expect_rc=0):
        p = subprocess.run(
            [sys.executable, "-c", _KILL_RESUME_SCRIPT, mode, str(tmp_path)],
            capture_output=True, text=True, env=_ENV, timeout=600,
        )
        assert p.returncode == expect_rc, (mode, p.stdout, p.stderr)
        return p.stdout

    full = run("full")
    run("part1", expect_rc=17)
    resumed = run("part2")
    assert full == resumed
    assert "OUT" in full and "COUNTERS" in full


@pytest.mark.slow
def test_mesh_shrink_degrades_to_gathered_descent_subprocess(tmp_path):
    """A forest built for an 8-device mesh, served on a shrunk 2-device
    mesh: on_mismatch="degrade" falls back to gathered single-device
    descent — elementwise-identical to sample_forest on the gathered
    forest, degraded=True in stats; the default still raises."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import sample_forest
        from repro.dist import forest as DF

        full = Mesh(np.asarray(jax.devices()).reshape(-1), ("data",))
        shrunk = Mesh(np.asarray(jax.devices()[:2]), ("data",))
        w = jnp.asarray(np.random.default_rng(0).random(256), jnp.float32)
        sf = DF.build_forest_sharded(w, 64, mesh=full)
        xi = jnp.asarray(np.random.default_rng(1).random(128), jnp.float32)
        try:
            DF.sample_sharded(sf, xi, mesh=shrunk)
            raise SystemExit("default on_mismatch must raise")
        except ValueError:
            pass
        got, stats = DF.sample_sharded(
            sf, xi, mesh=shrunk, on_mismatch="degrade", with_stats=True)
        assert stats["degraded"] is True, stats
        want = sample_forest(DF.gather_forest(sf), xi)
        assert np.array_equal(np.asarray(got), np.asarray(want))
        print("DEGRADE_OK")
    """)
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=_ENV, timeout=600)
    assert p.returncode == 0, p.stderr
    assert "DEGRADE_OK" in p.stdout


@pytest.mark.slow
def test_chaos_with_kill_resume_subprocess(tmp_path):
    """Chaos + kill: the fault plan runs in a process that dies mid-plan
    (kill_hook saves and _exits); a resumed chaos pool still passes
    verify_pool and keeps draining in-range."""
    script = textwrap.dedent("""
        import os, sys
        import numpy as np
        from repro.pool import ForestPool
        from repro.robust import load_serving, save_serving, verify_pool
        from repro.robust.faults import Fault, FaultPlan, run_chaos

        MODE, DIR = sys.argv[1], sys.argv[2]
        if MODE == "crash":
            plan = FaultPlan(tuple(
                [Fault(step=s, kind="bad_update", flavor="inf")
                 for s in (1, 3)] + [Fault(step=5, kind="kill")]))

            def hook(step):
                save_serving(DIR, step, marker=dict(step=step))
                os._exit(23)

            run_chaos(plan, steps=8, policy="quarantine", kill_hook=hook)
            raise SystemExit("kill hook did not fire")
        states, step = load_serving(DIR)
        assert step == 5 and states["marker"]["step"] == 5
        report = run_chaos(FaultPlan.default(steps=8, seed=4), steps=8,
                           policy="quarantine", seed=4)
        assert report["drains_equal"] and report["verify_errors"] == []
        print("CHAOS_RESUME_OK")
    """)
    p = subprocess.run([sys.executable, "-c", script, "crash", str(tmp_path)],
                       capture_output=True, text=True, env=_ENV, timeout=600)
    assert p.returncode == 23, (p.stdout, p.stderr)
    p = subprocess.run([sys.executable, "-c", script, "resume", str(tmp_path)],
                       capture_output=True, text=True, env=_ENV, timeout=600)
    assert p.returncode == 0, (p.stdout, p.stderr)
    assert "CHAOS_RESUME_OK" in p.stdout
