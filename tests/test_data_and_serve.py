"""Data pipeline (QMC mixture), serving engine, samplers, compression."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.data import MixtureSampler, make_batch
from repro.dist.compression import (
    compress_grads_with_feedback,
    dequantize_int8,
    quantize_int8,
)
from repro.models import init_params
from repro.serve import Request, ServeEngine, TokenSampler


def test_mixture_proportions_match_weights():
    w = [0.5, 0.25, 0.125, 0.125]
    ms = MixtureSampler(w, seed=0)
    ids = np.concatenate([ms.sample(step, 256) for step in range(8)])
    frac = np.bincount(ids, minlength=4) / len(ids)
    np.testing.assert_allclose(frac, w, atol=0.02)


def test_qmc_mixture_is_lower_variance():
    """The paper's core claim applied to the data layer: the monotone warp of
    a stratified stream tracks the mixture weights with lower per-batch
    dispersion than PRNG sampling."""
    w = np.asarray([0.4, 0.3, 0.2, 0.1])
    ms = MixtureSampler(w, seed=1)
    n, steps = 128, 50

    def dispersion(qmc: bool) -> float:
        errs = []
        for step in range(steps):
            ids = ms.sample(step, n, qmc=qmc)
            frac = np.bincount(ids, minlength=4) / n
            errs.append(np.sum((frac - w) ** 2))
        return float(np.mean(errs))

    assert dispersion(True) < 0.5 * dispersion(False)


def test_qmc_streams_duplicate_slots_draw_distinct_points():
    """Regression: a drain with a repeated slot must hand every occurrence
    its own stream point and advance the counter once per occurrence —
    fancy-index ``counters[slots] += 1`` collapsed duplicate increments and
    returned the same uniform for each occurrence (identical best-of-n
    candidates). The j-th occurrence (call order) must draw the exact point
    a twin stream draws when drained one occurrence at a time."""
    from repro.serve.sampler import QmcStreams

    s = QmcStreams(4, seed=9)
    twin = QmcStreams(4, seed=9)
    slots = np.asarray([2, 0, 2, 2, 1, 0])
    xi = s.next(slots)
    # duplicates draw distinct points...
    assert len(np.unique(xi[[0, 2, 3]])) == 3  # slot 2 x3
    assert xi[1] != xi[5]                      # slot 0 x2
    # ...and each occurrence advances exactly one counter step
    np.testing.assert_array_equal(s.counters, [2, 1, 3, 0])
    want = np.asarray([float(twin.next([int(t)])[0]) for t in slots],
                      np.float32)
    np.testing.assert_array_equal(xi, want)
    # a second drain continues the streams, disjoint from the first
    assert not np.intersect1d(s.next(slots), xi).size


def test_batches_deterministic_by_step():
    cfg = C.get_reduced("qwen1_5_0_5b")
    a = make_batch(cfg, 7, 4, 16, seed=3)
    b = make_batch(cfg, 7, 4, 16, seed=3)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = make_batch(cfg, 8, 4, 16, seed=3)
    assert not np.array_equal(a["tokens"], c["tokens"])


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = dataclasses.replace(
        C.get_reduced("qwen1_5_0_5b"), dtype="float32", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_serve_engine_continuous_batching(tiny_lm):
    cfg, params = tiny_lm
    rng = np.random.default_rng(0)
    eng = ServeEngine(params, cfg, n_slots=4, max_seq=64,
                      sampler=TokenSampler(n_slots=4, use_pallas=False))
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=rng.integers(3, 9)),
                max_new=rng.integers(4, 12))
        for i in range(7)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=200)
    for r in reqs:
        assert r.done
        assert len(r.out) >= min(r.max_new, 4)
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_serve_engine_isolation_under_load(tiny_lm):
    """A greedy (temperature->0) request must produce the same tokens whether
    decoded alone or co-batched with interfering traffic — continuous
    batching must not leak state across slots."""
    cfg, params = tiny_lm
    prompt = np.asarray([5, 9, 2, 7], np.int64)
    outs = []
    for load in (0, 3):
        sampler = TokenSampler(n_slots=4, temperature=1e-4, use_pallas=False, seed=1)
        eng = ServeEngine(params, cfg, n_slots=4, max_seq=64, sampler=sampler)
        target = Request(rid=0, prompt=prompt, max_new=8)
        eng.submit(target)
        rng = np.random.default_rng(5)
        for i in range(load):
            eng.submit(Request(rid=1 + i,
                               prompt=rng.integers(0, cfg.vocab, size=6),
                               max_new=6))
        eng.run(max_steps=100)
        outs.append(target.out)
    assert outs[0] == outs[1], outs


def test_serve_engine_mixed_model_and_prior_traffic(tiny_lm):
    """Model-backed and prior-backed (pool) requests co-batch in one engine:
    LM requests decode normally while prior tenants drain through the
    batched pool path, and retirement evicts every tenant."""
    cfg, params = tiny_lm
    rng = np.random.default_rng(3)
    eng = ServeEngine(params, cfg, n_slots=4, max_seq=64,
                      sampler=TokenSampler(n_slots=4, use_pallas=False))
    lm_reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5), max_new=5)
        for i in range(2)
    ]
    prior_reqs = [
        Request(rid=10 + i, prompt=np.zeros(1, np.int64), max_new=5,
                prior=rng.random(12) + 1e-3)
        for i in range(3)
    ]
    for r in lm_reqs + prior_reqs:
        eng.submit(r)
    eng.run(max_steps=100)
    for r in lm_reqs:
        assert r.done and all(0 <= t < cfg.vocab for t in r.out)
    for r in prior_reqs:
        assert r.done and all(0 <= t < 12 for t in r.out)
    assert eng.prior_sampler.pool.stats()["tenants"] == 0


def test_prior_slot_pos_stays_bounded_alongside_model_traffic(tiny_lm):
    """Regression: prior-backed slots used to run through the per-step pos
    increment even though they bypass the model, so a long-lived prior
    tenant's pos marched past max_seq — and pos doubles as decode_step's KV
    scatter index for EVERY batch row, so the stale writes walked across
    (then off) the cache budget. Prior slots' pos must stay frozen at 0
    while co-batched model traffic advances normally."""
    cfg, params = tiny_lm
    rng = np.random.default_rng(8)
    eng = ServeEngine(params, cfg, n_slots=3, max_seq=16,
                      sampler=TokenSampler(n_slots=3, use_pallas=False))
    prior_req = Request(rid=0, prompt=np.zeros(1, np.int64), max_new=40,
                        prior=rng.random(9) + 1e-3)
    lm_req = Request(rid=1, prompt=rng.integers(0, cfg.vocab, size=4),
                     max_new=10)
    eng.submit(prior_req)
    eng.submit(lm_req)
    prior_slot = None
    for _ in range(60):
        eng.step()
        if prior_slot is None and eng.prior_handles:
            prior_slot = next(iter(eng.prior_handles))
        if prior_slot is not None and prior_slot in eng.prior_handles:
            assert eng.pos[prior_slot] == 0
        assert np.all(eng.pos < eng.max_seq)
        if prior_req.done and lm_req.done:
            break
    # max_new=40 > max_seq=16: only a bounded pos lets the prior finish
    assert prior_req.done and len(prior_req.out) == 40
    assert lm_req.done and len(lm_req.out) == 10


def test_token_sampler_modes_agree_on_peaked_logits(tiny_lm):
    cfg, _ = tiny_lm
    logits = np.full((3, cfg.vocab), -20.0, np.float32)
    logits[0, 7] = 20.0
    logits[1, 100] = 20.0
    logits[2, 1] = 20.0
    lj = jnp.asarray(logits)
    for mode in ("inverse_qmc", "inverse_rng", "alias"):
        s = TokenSampler(mode=mode, n_slots=3, use_pallas=False)
        got = s.sample(lj, np.arange(3))
        np.testing.assert_array_equal(got, [7, 100, 1])


def test_token_sampler_alias_routes_through_slot_uniforms():
    """Regression: alias mode drew a FRESH ``self.rng.random()`` per row
    instead of routing through ``uniforms(slots)``, so inverse_rng-vs-alias
    comparisons never shared a draw sequence (the serving-diversity bench
    compared randomness, not mappings). Pin: override ``uniforms`` with a
    fixed vector and assert alias mode consumes exactly those values —
    matching a per-row build_alias + sample_alias oracle at the same xi."""
    import jax
    from repro.core.alias import build_alias, sample_alias

    rng = np.random.default_rng(5)
    logits = rng.normal(0, 2, (4, 32)).astype(np.float32)
    fixed = np.array([0.05, 0.93, 0.42, 0.61], np.float32)
    ts = TokenSampler(mode="alias", n_slots=4, seed=0, use_pallas=False)
    ts.uniforms = lambda slots: fixed[: len(slots)]
    got = ts.sample(jnp.asarray(logits), np.arange(4))
    p = np.asarray(jax.nn.softmax(jnp.asarray(logits), axis=-1))
    want = [
        int(np.asarray(sample_alias(build_alias(p[i]), jnp.float32(fixed[i]))))
        for i in range(4)
    ]
    np.testing.assert_array_equal(got, want)


def test_token_sampler_seeded_cross_mode_same_uniforms():
    """With the same seed, inverse_rng and alias consume the SAME uniform
    sequence (both through ``uniforms(slots)``), so the per-row alias
    oracle evaluated at inverse_rng's uniforms predicts alias mode's
    tokens exactly — a mode comparison now contrasts mappings only."""
    import jax
    from repro.core.alias import build_alias, sample_alias

    rng = np.random.default_rng(11)
    logits = rng.normal(0, 1.5, (6, 48)).astype(np.float32)
    lj = jnp.asarray(logits)
    seed = 123
    xi = np.random.default_rng(seed).random(6).astype(np.float32)  # the shared stream
    s_alias = TokenSampler(mode="alias", n_slots=6, seed=seed, use_pallas=False)
    got = s_alias.sample(lj, np.arange(6))
    p = np.asarray(jax.nn.softmax(lj, axis=-1))
    want = [
        int(np.asarray(sample_alias(build_alias(p[i]), jnp.float32(xi[i]))))
        for i in range(6)
    ]
    np.testing.assert_array_equal(got, want)
    # and inverse_rng with the same seed sees the same xi (shared protocol)
    s_inv = TokenSampler(mode="inverse_rng", n_slots=6, seed=seed,
                         use_pallas=False)
    np.testing.assert_array_equal(s_inv.uniforms(np.arange(6)), xi)


def test_int8_quantization_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.01, (256,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-12


def test_error_feedback_reduces_bias():
    """With error feedback the accumulated applied-gradient matches the true
    sum much better than naive repeated quantization."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(0, 1e-3, (512,)), jnp.float32)
    total_naive = np.zeros(512)
    total_fb = np.zeros(512)
    residual = None
    for _ in range(50):
        q, s = quantize_int8(g_true)
        total_naive += np.asarray(dequantize_int8(q, s))
        deq, residual = compress_grads_with_feedback(g_true, residual)
        total_fb += np.asarray(deq)
    want = np.asarray(g_true) * 50
    err_naive = np.linalg.norm(total_naive - want)
    err_fb = np.linalg.norm(total_fb - want)
    assert err_fb < err_naive * 0.5 or err_fb < 1e-6, (err_fb, err_naive)


def test_microbatch_accumulation_matches_full_batch():
    """grad-accum over 4 microbatches == single-batch step (float reorder
    noise only)."""
    import repro.configs as C
    from repro.models import init_params
    from repro.train.optimizer import AdamWConfig, init_opt
    from repro.train.step import make_train_step

    cfg = dataclasses.replace(
        C.get_reduced("qwen1_5_0_5b"), dtype="float32", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=128,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    oc = AdamWConfig()
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 128, (8, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 128, (8, 16)), jnp.int32),
    }
    s1 = jax.jit(make_train_step(cfg, oc, remat="none", microbatches=1))
    s4 = jax.jit(make_train_step(cfg, oc, remat="none", microbatches=4))
    p1, _, m1 = s1(params, init_opt(oc, params), batch)
    p4, _, m4 = s4(params, init_opt(oc, params), batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5


@pytest.mark.slow
def test_compressed_pod_allreduce_subprocess():
    """int8 cross-pod reduction: shared pre-agreed scale keeps the error at
    the quantization floor (a per-shard-scale bug showed 26% error)."""
    import subprocess
    import sys
    import textwrap
    import os

    script = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.dist.compression import make_pod_allreduce

        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1e-3, (4, 64)), jnp.float32)
        xs = jax.device_put(x, NamedSharding(mesh, P("pod")))
        want = np.broadcast_to(np.asarray(x).mean(0, keepdims=True), x.shape)
        with mesh:
            y = jax.jit(make_pod_allreduce(mesh, compress=True))(xs)
            y2 = jax.jit(make_pod_allreduce(mesh, compress=False))(xs)
        rel = np.abs(np.asarray(y) - want).max() / np.abs(want).max()
        assert rel < 0.02, rel
        assert np.allclose(np.asarray(y2), want, atol=1e-7)
        print("PSUM_OK", rel)
    """)
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=os.getcwd(), timeout=300)
    assert "PSUM_OK" in p.stdout, p.stdout + p.stderr
