"""Pool benchmarks: fused batched construction vs per-distribution loops,
and bulk mixed-size-class sampling throughput (repro.pool).

Sections (CSV; the structure gate pins rows and keys):

  pool_construction,B=...,n=...  — build B distributions at once (one fused
      vmapped program) vs B sequential ``build_forest`` calls. On this CPU
      the absolute us are anecdotal; the batched-vs-loop *ratio* is the
      reproducible fact (per-launch dispatch amortizes across the batch).
  pool_sampling,tenants=...,classes=...  — a ForestPool drain over mixed
      size classes: Q (tenant, uniform) pairs resolved with one batched
      launch per touched class, reported as us per drain and Msamples/s.
  pool_sampling,mix=...  — the stream-aware drain (device-side QMC counters,
      ``sample_streams``) per size-class mix, coalesced bucketing pre-pass
      vs raw scattered lane order. Draws are elementwise identical either
      way; the paired rows expose what tree-locality buys per mix.
  pool_sampling,method=...  — the SAME tenant set admitted twice, once per
      sampling method, drained with the same (tenant, uniform) pairs: the
      paper's tradeoff as paired rows — forest (monotone descent, QMC-safe)
      vs alias (packed O(1) tables, the bulk PRNG fast path).
  pool_construction,alias_build_batched,...  — the fused split-and-pack
      alias build (one kernel launch over B stacked rows) vs a loop of B
      host ``build_alias_parallel`` calls.
  pool_sampling,guard=...  — the SAME drain with and without the per-group
      invariant guard (``sample(..., guard=True)`` cross-checks each
      touched group's cdf/table before the launch): paired rows price the
      integrity check against the unguarded fast path.
  pool_snapshot,tenants=...  — serving-state durability: ``snapshot()``
      (host copy), ``save_state`` (atomic commit to disk), ``restore()``
      (arena rebuild), as us per operation at each tenant count.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_forest
from repro.core.alias import build_alias_parallel
from repro.core.cdf import normalize_weights
from repro.kernels import ops
from repro.pool import ForestPool, build_forest_batched


def _time(fn, reps: int = 3) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run_construction(batches=(16, 64), n: int = 1024):
    """Build-B-at-once vs loop-of-B: the fused builder's dispatch economy."""
    rows = []
    rng = np.random.default_rng(0)
    for B in batches:
        W = np.stack([
            normalize_weights(rng.random(n) ** 8 + 1e-12) for _ in range(B)
        ])
        Wj = jnp.asarray(W)

        def batched():
            f = build_forest_batched(Wj, n)
            jax.block_until_ready(f.left)

        def loop():
            for b in range(B):
                f = build_forest(Wj[b], n)
            jax.block_until_ready(f.left)

        t_b = _time(batched)
        t_l = _time(loop)
        rows.append(
            {
                "B": B, "n": n,
                "batched_us": t_b * 1e6, "loop_us": t_l * 1e6,
                "speedup": t_l / t_b,
                "meps": B * n / t_b / 1e6,
            }
        )
    return rows


def run_construction_alias(batches=(16, 64), n: int = 1024):
    """Fused split-and-pack alias build vs a host loop of parallel builds."""
    rows = []
    rng = np.random.default_rng(5)
    for B in batches:
        W = np.stack([
            normalize_weights(rng.random(n) ** 8 + 1e-12) for _ in range(B)
        ]).astype(np.float32)
        Wj = jnp.asarray(W)

        def batched():
            q, _ = ops.alias_build_batched(Wj, use_pallas=True)
            jax.block_until_ready(q)

        def loop():
            for b in range(B):
                build_alias_parallel(W[b])

        t_b = _time(batched)
        t_l = _time(loop)
        rows.append(
            {
                "B": B, "n": n,
                "batched_us": t_b * 1e6, "loop_us": t_l * 1e6,
                "speedup": t_l / t_b,
                "meps": B * n / t_b / 1e6,
            }
        )
    return rows


def run_sampling(tenants: int = 64, draws: int = 1 << 14):
    """Mixed-size-class drain throughput through a populated ForestPool.

    Three size classes (16/64/256) keep the interpret-mode Pallas compile
    count bounded on CPU; the drain itself is one launch per class."""
    rng = np.random.default_rng(1)
    pool = ForestPool()
    sizes = rng.choice([16, 64, 256], size=tenants)
    handles = pool.insert_many(
        [rng.random(s) ** 6 + 1e-9 for s in sizes]
    )
    qh = [handles[i] for i in rng.integers(0, tenants, draws)]
    xi = rng.random(draws).astype(np.float32)
    rows = []
    for label, use_pallas in (("pool_ref", False), ("pool_pallas", True)):
        t = _time(lambda: pool.sample(qh, xi, use_pallas=use_pallas), reps=3)
        rows.append(
            {
                "tenants": tenants,
                "classes": len(pool.classes),
                "path": label,
                "us": t * 1e6,
                "msps": draws / t / 1e6,
            }
        )
    return rows


_MIXES = {
    # size -> share of tenants; the serving-shaped sweep coordinates
    "uniform": {16: 1 / 3, 64: 1 / 3, 256: 1 / 3},
    "small_heavy": {16: 0.8, 64: 0.15, 256: 0.05},
    "large_heavy": {16: 0.05, 64: 0.15, 256: 0.8},
}


def run_sampling_mixes(tenants: int = 64, draws: int = 1 << 14):
    """Stream-aware drain throughput per size-class mix, coalesced vs
    scattered lane order. One ``DeviceQmcStreams`` pre-pass + one
    ``forest_sample_batched_streams`` launch per touched class; the
    ``coalesce`` toggle flips only the kernel's bucketing pre-pass, so the
    pair isolates what walking per-tree runs buys for each tenant shape."""
    from repro.serve.sampler import DeviceQmcStreams

    rows = []
    for mix, shares in _MIXES.items():
        rng = np.random.default_rng(2)
        pool = ForestPool()
        sizes = rng.choice(
            sorted(shares), size=tenants,
            p=np.asarray([shares[s] for s in sorted(shares)]),
        )
        handles = pool.insert_many([rng.random(s) ** 6 + 1e-9 for s in sizes])
        qh = [handles[i] for i in rng.integers(0, tenants, draws)]
        slots = rng.integers(0, tenants, draws)
        streams = DeviceQmcStreams(tenants, seed=3)
        for label, coalesce in (("stream_coalesced", True),
                                ("stream_scatter", False)):
            t = _time(
                lambda: pool.sample_streams(
                    qh, slots, streams, use_pallas=True, coalesce=coalesce
                ),
                reps=3,
            )
            rows.append(
                {
                    "mix": mix, "path": label, "tenants": tenants,
                    "classes": len(pool.classes),
                    "us": t * 1e6, "msps": draws / t / 1e6,
                }
            )
    return rows


def run_sampling_methods(tenants: int = 64, draws: int = 1 << 14):
    """Forest vs alias drains over the SAME tenants and the same (tenant,
    uniform) pairs — the per-slot method attribute as a paired benchmark.
    Each pool drains with one launch per touched (method, size class)."""
    rng = np.random.default_rng(4)
    sizes = rng.choice([16, 64, 256], size=tenants)
    tens = [rng.random(s) ** 6 + 1e-9 for s in sizes]
    qidx = rng.integers(0, tenants, draws)
    xi = rng.random(draws).astype(np.float32)
    rows = []
    for method in ("forest", "alias"):
        pool = ForestPool()
        handles = pool.insert_many(tens, method=method)
        qh = [handles[i] for i in qidx]
        t = _time(lambda: pool.sample(qh, xi, use_pallas=True), reps=3)
        rows.append(
            {
                "method": method, "tenants": tenants,
                "classes": len(pool.classes) + len(pool.alias_classes),
                "us": t * 1e6, "msps": draws / t / 1e6,
            }
        )
    return rows


def run_sampling_guard(tenants: int = 64, draws: int = 1 << 14):
    """The invariant guard's price: the same mixed-class drain with
    ``guard=True`` (per-group cdf/table cross-checks before each launch)
    vs the unguarded fast path. Draws are identical either way."""
    rng = np.random.default_rng(6)
    pool = ForestPool()
    sizes = rng.choice([16, 64, 256], size=tenants)
    methods = ["forest" if i % 2 == 0 else "alias" for i in range(tenants)]
    handles = pool.insert_many(
        [rng.random(s) ** 6 + 1e-9 for s in sizes], method=methods
    )
    qh = [handles[i] for i in rng.integers(0, tenants, draws)]
    xi = rng.random(draws).astype(np.float32)
    rows = []
    for label, guard in (("off", False), ("on", True)):
        t = _time(lambda: pool.sample(qh, xi, guard=guard), reps=3)
        rows.append(
            {
                "guard": label, "tenants": tenants,
                "classes": len(pool.classes) + len(pool.alias_classes),
                "us": t * 1e6, "msps": draws / t / 1e6,
            }
        )
    return rows


def run_snapshot(tenant_counts=(16, 64)):
    """Serving-state durability cost: host snapshot, atomic on-disk commit
    (``repro.ckpt.save_state``), and arena rebuild on restore."""
    import shutil
    import tempfile

    from repro.ckpt import save_state

    rng = np.random.default_rng(7)
    rows = []
    for tenants in tenant_counts:
        pool = ForestPool()
        sizes = rng.choice([16, 64, 256], size=tenants)
        methods = ["forest" if i % 2 == 0 else "alias"
                   for i in range(tenants)]
        pool.insert_many(
            [rng.random(s) ** 6 + 1e-9 for s in sizes], method=methods
        )
        t_snap = _time(lambda: pool.snapshot(), reps=3)
        state = pool.snapshot()
        tmp = tempfile.mkdtemp(prefix="pool_snap_bench_")
        try:
            step = [0]

            def save():
                step[0] += 1
                save_state(tmp, state, step[0])

            t_save = _time(save, reps=3)
            t_rest = _time(lambda: ForestPool.restore(state), reps=3)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        rows.append(
            {
                "tenants": tenants,
                "classes": len(pool.classes) + len(pool.alias_classes),
                "snapshot_us": t_snap * 1e6, "save_us": t_save * 1e6,
                "restore_us": t_rest * 1e6,
            }
        )
    return rows


def main_construction() -> list[str]:
    rows = [
        f"pool_construction,B={r['B']},n={r['n']},"
        f"batched_us={r['batched_us']:.0f},loop_us={r['loop_us']:.0f},"
        f"batched_vs_loop={r['speedup']:.2f},"
        f"batched_Mentries_s={r['meps']:.2f}"
        for r in run_construction()
    ]
    rows += [
        f"pool_construction,alias_build_batched,B={r['B']},n={r['n']},"
        f"batched_us={r['batched_us']:.0f},host_loop_us={r['loop_us']:.0f},"
        f"batched_vs_loop={r['speedup']:.2f},"
        f"batched_Mentries_s={r['meps']:.2f}"
        for r in run_construction_alias()
    ]
    return rows


def main_sampling() -> list[str]:
    rows = [
        f"pool_sampling,{r['path']},tenants={r['tenants']},"
        f"classes={r['classes']},us_per_drain={r['us']:.0f},"
        f"Msamples_s={r['msps']:.2f}"
        for r in run_sampling()
    ]
    rows += [
        f"pool_sampling,mix={r['mix']},{r['path']},tenants={r['tenants']},"
        f"classes={r['classes']},us_per_drain={r['us']:.0f},"
        f"Msamples_s={r['msps']:.2f}"
        for r in run_sampling_mixes()
    ]
    rows += [
        f"pool_sampling,method={r['method']},tenants={r['tenants']},"
        f"classes={r['classes']},us_per_drain={r['us']:.0f},"
        f"Msamples_s={r['msps']:.2f}"
        for r in run_sampling_methods()
    ]
    rows += [
        f"pool_sampling,guard={r['guard']},tenants={r['tenants']},"
        f"classes={r['classes']},us_per_drain={r['us']:.0f},"
        f"Msamples_s={r['msps']:.2f}"
        for r in run_sampling_guard()
    ]
    return rows


def main_snapshot() -> list[str]:
    return [
        f"pool_snapshot,tenants={r['tenants']},classes={r['classes']},"
        f"snapshot_us={r['snapshot_us']:.0f},save_us={r['save_us']:.0f},"
        f"restore_us={r['restore_us']:.0f}"
        for r in run_snapshot()
    ]


def main() -> list[str]:
    return main_construction() + main_sampling() + main_snapshot()


if __name__ == "__main__":
    print("\n".join(main()))
