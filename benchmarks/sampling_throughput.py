"""Sampling throughput (us/call over batches): forest traversal vs binary
search vs cutpoint+binary vs alias, in both pure-XLA and Pallas-interpret
forms. The paper's Table-1 'average_32' models exactly the vector-lane
lock-step this batch timing measures on real hardware.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    build_alias,
    build_forest,
    sample_alias,
    sample_binary,
    sample_cutpoint_binary,
    sample_forest,
)
from repro.core.cdf import normalize_weights
from repro.kernels import ops


def _time(fn, reps: int = 10) -> float:
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(n: int = 1 << 16, m: int | None = None, batch: int = 1 << 16):
    m = m or n
    rng = np.random.default_rng(0)
    w = normalize_weights(rng.random(n) ** 12 + 1e-12)
    f = build_forest(jnp.asarray(w), m)
    at = build_alias(w)
    xi = jnp.asarray(rng.random(batch), jnp.float32)

    sb = jax.jit(lambda u: sample_binary(f.cdf, u))
    scb = jax.jit(lambda u: sample_cutpoint_binary(f.cdf, f.cell_first, u))
    sf = jax.jit(lambda u: sample_forest(f, u))
    sa = jax.jit(lambda u: sample_alias(at, u))

    rows = [
        ("binary_search", _time(lambda: sb(xi))),
        ("cutpoint_binary", _time(lambda: scb(xi))),
        ("forest_alg2", _time(lambda: sf(xi))),
        ("alias", _time(lambda: sa(xi))),
        ("forest_pallas_interpret",
         _time(lambda: ops.forest_sample(f, xi), reps=3)),
    ]
    return [(name, us, batch / us) for name, us in rows]


def run_sharded(n: int = 1 << 16, batch: int = 1 << 16):
    """Sampling over the cell-partitioned *windowed* forest across
    fake-device counts (repro.dist.forest.sample_sharded), both paths:

      * ``forest_sharded_d{D}``        — replicated masked-psum oracle
        (every shard descends the full batch; kept as the reference).
      * ``forest_sharded_routed_d{D}`` — owner-routed all-to-all bulk
        drain; each shard descends only its capacity-padded ~B/D bucket.

    Each row reports the static per-device leaf window the descent runs
    over; routed rows additionally report the per-(src,dst) bucket
    capacity — the descent lane count is D*bucket per shard, vs the full
    padded batch on the oracle. Full sweep needs
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    from jax.sharding import Mesh

    from repro.dist import forest as DF

    rng = np.random.default_rng(0)
    w = normalize_weights(rng.random(n) ** 12 + 1e-12)
    xi = jnp.asarray(rng.random(batch), jnp.float32)
    devices = jax.devices()
    rows = []
    for D in (c for c in (1, 2, 4, 8) if c <= len(devices)):
        mesh = Mesh(np.asarray(devices[:D]), ("data",))
        sf = DF.build_forest_sharded(jnp.asarray(w), n, mesh=mesh)
        us = _time(
            lambda: DF.sample_sharded(sf, xi, mesh=mesh, routed=False), reps=5
        )
        rows.append(
            {
                "name": f"forest_sharded_d{D}", "us": us, "mps": batch / us,
                "window": sf.capacity,
            }
        )
        plan = DF.drain_plan(sf, xi, mesh=mesh)
        us = _time(
            lambda: DF.sample_sharded(sf, xi, mesh=mesh, routed=True), reps=5
        )
        rows.append(
            {
                "name": f"forest_sharded_routed_d{D}", "us": us,
                "mps": batch / us, "window": sf.capacity,
                "bucket": plan["bucket_capacity"],
            }
        )
    return rows


def main() -> list[str]:
    lines = [
        f"throughput,{name},us_per_call={us:.0f},Msamples_s={mps:.2f}"
        for name, us, mps in run()
    ]
    for r in run_sharded():
        line = (
            f"throughput,{r['name']},us_per_call={r['us']:.0f},"
            f"Msamples_s={r['mps']:.2f},window={r['window']}"
        )
        if "bucket" in r:
            line += f",bucket={r['bucket']}"
        lines.append(line)
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
