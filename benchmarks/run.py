"""Benchmark harness: one module per paper table/figure + systems benches.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,...`` CSV lines per benchmark (format per module docstrings).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import construction, convergence, sampling_throughput, serving_diversity, table1

    sections = [
        ("Table 1 (load counts)", table1.main),
        ("Figs 7/9/1 (QMC convergence & discrepancy)",
         (lambda: _convergence_quick()) if quick else convergence.main),
        ("Construction throughput", construction.main),
        ("Sampling throughput", sampling_throughput.main),
        ("Serving best-of-n diversity", serving_diversity.main),
    ]
    for title, fn in sections:
        t0 = time.time()
        print(f"# === {title} ===", flush=True)
        for line in fn():
            print(line, flush=True)
        print(f"# ({time.time() - t0:.1f}s)", flush=True)


def _convergence_quick():
    from benchmarks import convergence

    out = []
    for n, e_inv, e_ali in convergence.run_1d(max_log2=14):
        out.append(
            f"fig7_1d,n={n},err_inverse={e_inv:.3e},err_alias={e_ali:.3e},"
            f"ratio={e_ali / max(e_inv, 1e-30):.2f}"
        )
    for n, e_inv, e_ali in convergence.run_2d(max_log2=14, h=64, w=128):
        out.append(
            f"fig9_2d,n={n},err_inverse={e_inv:.3e},err_alias={e_ali:.3e},"
            f"ratio={e_ali / max(e_inv, 1e-30):.2f}"
        )
    d = convergence.run_discrepancy(2048)
    out.append(
        f"fig1_discrepancy,input={d['input']:.4f},inverse={d['inverse']:.4f},"
        f"alias={d['alias']:.4f}"
    )
    return out


if __name__ == "__main__":
    main()
