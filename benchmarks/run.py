"""Benchmark harness: one module per paper table/figure + systems benches.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--json OUT.json]

Prints ``name,...`` CSV lines per benchmark (format per module docstrings).
``--json`` additionally writes a machine-readable record ``{section:
{lines: [...], seconds: float}}`` — ``BENCH_baseline.json`` in the repo root
is one such record, committed so future PRs have a perf trajectory to diff
against (same CSV keys, CPU, --quick).
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", dest="json_out", metavar="OUT.json", default=None)
    args = ap.parse_args()
    quick, json_out = args.quick, args.json_out
    from benchmarks import (
        construction,
        convergence,
        pool,
        sampling_throughput,
        serving_diversity,
        spatial,
        table1,
    )

    sections = [
        ("Table 1 (load counts)", table1.main),
        ("Figs 7/9/1 (QMC convergence & discrepancy)",
         (lambda: _convergence_quick()) if quick else convergence.main),
        ("Construction throughput", construction.main),
        ("Pool construction", pool.main_construction),
        ("Sampling throughput", sampling_throughput.main),
        ("Pool sampling", pool.main_sampling),
        ("Pool snapshot", pool.main_snapshot),
        ("Serving best-of-n diversity", serving_diversity.main),
        ("Map2D construction", spatial.main_construction),
        ("Map2D sampling", spatial.main_sampling),
    ]
    record: dict[str, dict] = {}
    for title, fn in sections:
        t0 = time.time()
        print(f"# === {title} ===", flush=True)
        lines = []
        for line in fn():
            print(line, flush=True)
            lines.append(line)
        dt = time.time() - t0
        print(f"# ({dt:.1f}s)", flush=True)
        record[title] = {"lines": lines, "seconds": round(dt, 2)}
    if json_out:
        meta = {"quick": quick}
        with open(json_out, "w") as fh:
            json.dump({"meta": meta, "sections": record}, fh, indent=2)
        print(f"# wrote {json_out}", flush=True)


def _convergence_quick():
    from benchmarks import convergence

    out = []
    for n, e_inv, e_ali in convergence.run_1d(max_log2=14):
        out.append(
            f"fig7_1d,n={n},err_inverse={e_inv:.3e},err_alias={e_ali:.3e},"
            f"ratio={e_ali / max(e_inv, 1e-30):.2f}"
        )
    for n, e_inv, e_ali in convergence.run_2d(max_log2=14, h=64, w=128):
        out.append(
            f"fig9_2d,n={n},err_inverse={e_inv:.3e},err_alias={e_ali:.3e},"
            f"ratio={e_ali / max(e_inv, 1e-30):.2f}"
        )
    d = convergence.run_discrepancy(2048)
    out.append(
        f"fig1_discrepancy,input={d['input']:.4f},inverse={d['inverse']:.4f},"
        f"alias={d['alias']:.4f}"
    )
    return out


if __name__ == "__main__":
    main()
