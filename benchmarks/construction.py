"""Construction throughput: the paper's headline systems claim — forest
build is one parallel pass (here: vectorized XLA program, zero atomics)
while the Alias-Method build is inherently serial (Vose two-pass work
lists). Reports us per build and throughput in M entries/s across n.

On this 1-core CPU the absolute numbers are anecdotal; the scaling *shape*
(flat parallel work vs linear serial work) and the code-path structure are
the reproducible facts. The paper's GPU speedup comes from exactly the
parallelism the vectorized builder exposes.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_alias, build_forest_from_cdf, np_build_cdf
from repro.core.alias import build_alias_parallel
from repro.core.cdf import normalize_weights


def _time(fn, reps: int = 5) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(sizes=(1 << 12, 1 << 16, 1 << 20)):
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        w = normalize_weights(rng.random(n) ** 8 + 1e-12)
        cdf = jnp.asarray(np_build_cdf(w))
        m = n

        def build():
            f = build_forest_from_cdf(cdf, m)
            jax.block_until_ready(f.left)

        t_forest = _time(build)
        t_alias = _time(lambda: build_alias(w), reps=2)
        t_palias = _time(lambda: build_alias_parallel(w), reps=2)
        rows.append(
            {
                "n": n,
                "forest_us": t_forest * 1e6,
                "alias_us": t_alias * 1e6,
                "palias_us": t_palias * 1e6,
                "forest_meps": n / t_forest / 1e6,
                "alias_meps": n / t_alias / 1e6,
                "palias_meps": n / t_palias / 1e6,
            }
        )
    return rows


def run_sharded(sizes=(1 << 12, 1 << 16)):
    """Cell-partitioned *windowed* sharded build (repro.dist.forest) across
    fake-device counts. On one CPU core the fake devices time-slice, so
    absolute us numbers mostly show the collective overhead; the row
    structure, the device-count sweep, and the windowed per-device work
    columns (``window`` = static local leaf-window size, ``capacity_util`` =
    mean owned leaves / window) are what CI's bench-regression gate pins.
    Set XLA_FLAGS=--xla_force_host_platform_device_count=8 for the full
    sweep."""
    from jax.sharding import Mesh

    from repro.dist import forest as DF

    rows = []
    rng = np.random.default_rng(0)
    devices = jax.devices()
    counts = [c for c in (1, 2, 4, 8) if c <= len(devices)]
    for n in sizes:
        w = jnp.asarray(normalize_weights(rng.random(n) ** 8 + 1e-12))
        for D in counts:
            mesh = Mesh(np.asarray(devices[:D]), ("data",))
            f = None

            def build():
                nonlocal f
                f = DF.build_forest_sharded(w, n, mesh=mesh)
                jax.block_until_ready(f.left)

            t = _time(build, reps=3)
            rows.append(
                {
                    "n": n, "devices": D, "us": t * 1e6, "meps": n / t / 1e6,
                    "window": f.capacity,
                    "util": float(np.asarray(f.window_count).mean())
                    / f.capacity,
                }
            )
    return rows


def run_delta(sizes=(1 << 12,)):
    """Delta updates vs from-scratch sharded rebuilds (update_forest_sharded
    at the ambient device count): a no-op delta, a sparse perturbation, and
    an all-cells-changed reweight. Integer-valued weights keep the scan
    exact so the sparse case really does leave most shards' windows clean."""
    from repro.dist import forest as DF

    rows = []
    rng = np.random.default_rng(0)
    D = len(jax.devices())
    for n in sizes:
        w0 = rng.integers(2, 50, n).astype(np.float32)
        sf0 = DF.build_forest_sharded(jnp.asarray(w0), n)
        part = np.asarray(sf0.cell_bounds)

        def full_rebuild(w):
            f = DF.build_forest_sharded(jnp.asarray(w), n, partition=part)
            jax.block_until_ready(f.left)

        w_sparse = w0.copy()
        w_sparse[n // 2] += 1.0
        w_sparse[n // 2 + 1] -= 1.0
        w_full = rng.random(n).astype(np.float32) + np.float32(1e-3)
        for kind, w_new in (
            ("noop", w0), ("sparse", w_sparse), ("full", w_full)
        ):
            stats = None

            def update():
                nonlocal stats
                f, stats = DF.update_forest_sharded(
                    sf0, jnp.asarray(w_new), with_stats=True
                )
                jax.block_until_ready(f.left)

            t_upd = _time(update, reps=3)
            t_full = _time(lambda: full_rebuild(w_new), reps=3)
            rows.append(
                {
                    "n": n, "devices": D, "kind": kind,
                    "update_us": t_upd * 1e6, "full_us": t_full * 1e6,
                    "dirty_shards": stats["dirty_shards"],
                    "dirty_chunks": stats["dirty_chunks"],
                    "rebuilt_windows": stats["rebuilt_windows"],
                }
            )
    return rows


def main() -> list[str]:
    lines = [
        f"construction,n={r['n']},forest_us={r['forest_us']:.0f},"
        f"alias_vose_us={r['alias_us']:.0f},alias_parallel_us={r['palias_us']:.0f},"
        f"forest_Mentries_s={r['forest_meps']:.2f},"
        f"alias_vose_Mentries_s={r['alias_meps']:.2f},"
        f"alias_parallel_Mentries_s={r['palias_meps']:.2f}"
        for r in run()
    ]
    lines += [
        f"construction_sharded,n={r['n']},devices={r['devices']},"
        f"forest_us={r['us']:.0f},forest_Mentries_s={r['meps']:.2f},"
        f"window={r['window']},capacity_util={r['util']:.2f}"
        for r in run_sharded()
    ]
    lines += [
        f"construction_delta,n={r['n']},devices={r['devices']},"
        f"kind={r['kind']},update_us={r['update_us']:.0f},"
        f"full_rebuild_us={r['full_us']:.0f},"
        f"dirty_shards={r['dirty_shards']},dirty_chunks={r['dirty_chunks']},"
        f"rebuilt_windows={r['rebuilt_windows']}"
        for r in run_delta()
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
