"""Construction throughput: the paper's headline systems claim — forest
build is one parallel pass (here: vectorized XLA program, zero atomics)
while the Alias-Method build is inherently serial (Vose two-pass work
lists). Reports us per build and throughput in M entries/s across n.

On this 1-core CPU the absolute numbers are anecdotal; the scaling *shape*
(flat parallel work vs linear serial work) and the code-path structure are
the reproducible facts. The paper's GPU speedup comes from exactly the
parallelism the vectorized builder exposes.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_alias, build_forest_from_cdf, np_build_cdf
from repro.core.alias import build_alias_parallel
from repro.core.cdf import normalize_weights


def _time(fn, reps: int = 5) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(sizes=(1 << 12, 1 << 16, 1 << 20)):
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        w = normalize_weights(rng.random(n) ** 8 + 1e-12)
        cdf = jnp.asarray(np_build_cdf(w))
        m = n

        def build():
            f = build_forest_from_cdf(cdf, m)
            jax.block_until_ready(f.left)

        t_forest = _time(build)
        t_alias = _time(lambda: build_alias(w), reps=2)
        t_palias = _time(lambda: build_alias_parallel(w), reps=2)
        rows.append(
            {
                "n": n,
                "forest_us": t_forest * 1e6,
                "alias_us": t_alias * 1e6,
                "palias_us": t_palias * 1e6,
                "forest_meps": n / t_forest / 1e6,
                "alias_meps": n / t_alias / 1e6,
                "palias_meps": n / t_palias / 1e6,
            }
        )
    return rows


def run_sharded(sizes=(1 << 12, 1 << 16)):
    """Cell-partitioned sharded build (repro.dist.forest) across fake-device
    counts. On one CPU core the fake devices time-slice, so absolute us
    numbers mostly show the collective overhead; the row structure and the
    device-count sweep are what CI's bench-regression gate pins. Set
    XLA_FLAGS=--xla_force_host_platform_device_count=8 for the full sweep."""
    from jax.sharding import Mesh

    from repro.dist import forest as DF

    rows = []
    rng = np.random.default_rng(0)
    devices = jax.devices()
    counts = [c for c in (1, 2, 4, 8) if c <= len(devices)]
    for n in sizes:
        w = jnp.asarray(normalize_weights(rng.random(n) ** 8 + 1e-12))
        for D in counts:
            mesh = Mesh(np.asarray(devices[:D]), ("data",))

            def build():
                f = DF.build_forest_sharded(w, n, mesh=mesh)
                jax.block_until_ready(f.left)

            t = _time(build, reps=3)
            rows.append(
                {"n": n, "devices": D, "us": t * 1e6, "meps": n / t / 1e6}
            )
    return rows


def main() -> list[str]:
    lines = [
        f"construction,n={r['n']},forest_us={r['forest_us']:.0f},"
        f"alias_vose_us={r['alias_us']:.0f},alias_parallel_us={r['palias_us']:.0f},"
        f"forest_Mentries_s={r['forest_meps']:.2f},"
        f"alias_vose_Mentries_s={r['alias_meps']:.2f},"
        f"alias_parallel_Mentries_s={r['palias_meps']:.2f}"
        for r in run()
    ]
    lines += [
        f"construction_sharded,n={r['n']},devices={r['devices']},"
        f"forest_us={r['us']:.0f},forest_Mentries_s={r['meps']:.2f}"
        for r in run_sharded()
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
