"""Paper Table 1: memory-load counts (maximum / average / average_32) for
Cutpoint+binary-search vs Cutpoint+radix-forest on the four distributions
of Fig. 12. n, m are not stated in the paper; defaults n=256, m=256
reproduce the magnitudes (see EXPERIMENTS.md §Paper for the comparison).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.paper_workloads import TABLE1
from repro.core import (
    build_forest,
    np_sample_cutpoint_binary_counting,
    np_sample_forest_counting,
    table1_row,
)


def run(n: int = 256, m: int = 256, n_samples: int = 1 << 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    xi = rng.random(n_samples).astype(np.float32)
    rows = []
    for name, make in TABLE1.items():
        w = make(n)
        f = build_forest(jnp.asarray(w), m)
        cdf = np.asarray(f.cdf)
        cell_first = np.asarray(f.cell_first)
        table = np.asarray(f.table)
        i_b, loads_b = np_sample_cutpoint_binary_counting(cdf, cell_first, table, xi)
        i_f, loads_f = np_sample_forest_counting(f, xi)
        assert np.all(cdf[i_b] == cdf[i_f]), name
        rows.append((name, "cutpoint+binary", table1_row(loads_b)))
        rows.append((name, "cutpoint+radix_forest", table1_row(loads_f)))
    return rows


PAPER = {  # the paper's reported numbers for side-by-side context
    ("i^20", "cutpoint+binary"): (8, 1.25, 3.66),
    ("i^20", "cutpoint+radix_forest"): (16, 1.23, 3.46),
    ("(i mod 32 + 1)^25", "cutpoint+binary"): (6, 1.30, 4.62),
    ("(i mod 32 + 1)^25", "cutpoint+radix_forest"): (13, 1.22, 3.72),
    ("(i mod 64 + 1)^35", "cutpoint+binary"): (7, 1.19, 4.33),
    ("(i mod 64 + 1)^35", "cutpoint+radix_forest"): (13, 1.11, 2.46),
    ("4 spikes", "cutpoint+binary"): (4, 1.60, 3.98),
    ("4 spikes", "cutpoint+radix_forest"): (5, 1.67, 4.93),
}


def main() -> list[str]:
    out = []
    for name, method, row in run():
        p = PAPER.get((name, method))
        paper_s = f" | paper: max={p[0]} avg={p[1]:.2f} avg32={p[2]:.2f}" if p else ""
        out.append(
            f"table1,{name},{method},max={row['maximum']},"
            f"avg={row['average']:.2f},avg32={row['average_32']:.2f}{paper_s}"
        )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
