"""2-D map serving benchmarks: the ``repro.spatial`` bulk pipeline vs the
per-row loops it replaces (paper Sec. 5 / Fig. 8 served at bulk granularity).

Sections (CSV; the structure gate pins rows and keys):

  map2d_construction,H=...,W=...  — a :class:`Map2DSampler` build (marginal
      forest + ONE ``build_forest_rows`` launch per pow2 width class) vs the
      old loop: one marginal build + H per-row ``build_forest`` calls. The
      ``launches`` column is the structural fact: classes + 1, independent
      of H.
  map2d_sampling,H=...,W=...  — a bulk ``sample_map`` drain (marginal
      descent + one batched conditional launch per touched class) vs the
      row-then-column reference looping ``sample_forest`` over every
      distinct sampled row. ``launches`` vs ``distinct_rows`` is the
      one-launch-per-class (never per-row) witness.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.paper_workloads import env_map_2d
from repro.core import build_forest, sample_forest
from repro.core.cdf import normalize_weights
from repro.spatial import Map2DSampler


def _time(fn, reps: int = 3) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run_construction(shapes=((16, 64), (64, 128))):
    """Whole-map build: class-stacked multi-row launches vs H per-row
    builds. Both sides normalize on the host and end device-synced."""
    rows = []
    for H, W in shapes:
        img = env_map_2d(H, W)

        def bulk():
            s = Map2DSampler(img)
            jax.block_until_ready(next(iter(s.classes.values())).forest.left)

        def loop():
            build_forest(jnp.asarray(normalize_weights(img.sum(axis=1))), H)
            for r in range(H):
                f = build_forest(jnp.asarray(normalize_weights(img[r])), W)
            jax.block_until_ready(f.left)

        t_b = _time(bulk)
        t_l = _time(loop)
        sampler = Map2DSampler(img)
        rows.append(
            {
                "H": H, "W": W,
                "bulk_us": t_b * 1e6, "loop_us": t_l * 1e6,
                "speedup": t_l / t_b,
                "launches": len(sampler.classes) + 1,  # + the marginal
            }
        )
    return rows


def run_sampling(shapes=((16, 64), (64, 128)), draws: int = 1 << 14):
    """Bulk drain vs the per-distinct-row reference loop. The reference
    pre-builds every per-row forest (construction is the other section) —
    the loop pays one ``sample_forest`` dispatch per distinct sampled row,
    the bulk path one batched launch per touched size class."""
    rows = []
    rng = np.random.default_rng(0)
    for H, W in shapes:
        img = env_map_2d(H, W)
        sampler = Map2DSampler(img)
        pts = rng.random((draws, 2)).astype(np.float32)
        u, v = jnp.asarray(pts[:, 0]), jnp.asarray(pts[:, 1])

        wc = int(sampler._class_of[0])
        marg = build_forest(
            jnp.asarray(normalize_weights(img.sum(axis=1))),
            sampler.m_marginal,
        )
        per_row = [
            build_forest(
                jnp.asarray(np.pad(normalize_weights(img[r]), (0, wc - W))),
                wc,
            )
            for r in range(H)
        ]

        def bulk():
            r, c, _, _ = sampler.sample_map(pts)
            return c

        def loop():
            rr = np.asarray(sample_forest(marg, u), np.int64)
            out = np.empty(draws, np.int64)
            for r in np.unique(rr):
                mask = rr == r
                out[mask] = np.minimum(
                    np.asarray(sample_forest(per_row[r], v[mask])), W - 1
                )
            return out

        t_b = _time(bulk)
        t_l = _time(loop)
        ri, ci, _, _ = sampler.sample_map(pts)
        distinct = len(np.unique(ri))
        rows.append(
            {
                "H": H, "W": W,
                "bulk_us": t_b * 1e6, "loop_us": t_l * 1e6,
                "speedup": t_l / t_b,
                "msps": draws / t_b / 1e6,
                "launches": sampler.last_drain["launches"],
                "distinct_rows": distinct,
            }
        )
    return rows


def main_construction() -> list[str]:
    return [
        f"map2d_construction,H={r['H']},W={r['W']},"
        f"bulk_us={r['bulk_us']:.0f},loop_us={r['loop_us']:.0f},"
        f"bulk_vs_loop={r['speedup']:.2f},launches={r['launches']}"
        for r in run_construction()
    ]


def main_sampling() -> list[str]:
    return [
        f"map2d_sampling,H={r['H']},W={r['W']},"
        f"bulk_us={r['bulk_us']:.0f},loop_us={r['loop_us']:.0f},"
        f"bulk_vs_loop={r['speedup']:.2f},Msamples_s={r['msps']:.2f},"
        f"launches={r['launches']},distinct_rows={r['distinct_rows']}"
        for r in run_sampling()
    ]


def main() -> list[str]:
    return main_construction() + main_sampling()


if __name__ == "__main__":
    print("\n".join(main()))
