"""Bench structure gate: the --quick JSON must keep the baseline's shape.

  PYTHONPATH=src python -m benchmarks.check_regression \
      [--baseline BENCH_baseline.json] [--out /tmp/bench_now.json] [--reuse]

Regenerates the quick benchmark record (subprocess ``benchmarks.run --quick
--json``) and fails (exit 1) when any *section* or *CSV key* present in the
committed ``BENCH_baseline.json`` is missing or renamed in the fresh run.
Numeric values are free to drift — that drift IS the perf trajectory the
baseline exists to expose — but silently dropping a benchmark row or renaming
a column would blind every future diff, which is exactly what this gate
catches. Run (and CI runs it) with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the sharded
device-count sweep rows are present.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
from collections import Counter

_NUM = re.compile(r"^-?\d+(\.\d+)?([eE][+-]?\d+)?$")

# Sweep coordinates: numeric, but structural — a row is identified by them
# (n=65536 vanishing from the construction sweep IS a missing row, not value
# drift). Measurements (us, Mentries_s, max/avg/...) stay free to drift.
# "B"/"tenants"/"classes" identify the pool rows (batched-build batch size
# and the mixed-size-class drain shape). "bucket" is the routed drain's
# per-(src,dst) bucket capacity on the forest_sharded_routed_d* rows —
# deterministic under the fixed bench seed, and the structural witness that
# each shard descends ~B/D lanes instead of the full batch. "mix" names the
# size-class mix of the paired coalesced-vs-scatter stream-drain rows (its
# values are labels, not measurements, so each mix row is structural).
# "method" names the per-slot sampling method of the paired forest-vs-alias
# pool drain rows — losing either side of the pair IS a missing row.
# "H"/"W" identify the 2-D map shape of the spatial (Map2D) sweep rows.
# "guard" names the paired guarded-vs-unguarded drain rows (the invariant
# check's price) — dropping either side of the pair IS a missing row.
_PARAMS = frozenset(
    {"n", "m", "devices", "B", "tenants", "classes", "bucket", "mix",
     "method", "H", "W", "guard"}
)


def line_key(line: str) -> str:
    """Structural key of a CSV line: measurement values are stripped (they
    may drift); names, non-numeric values (method labels), and sweep
    coordinates (``_PARAMS``, e.g. ``n=65536``, ``devices=8``) are kept.
    Paper annotations after ``' | '`` carry no keys."""
    parts = []
    for part in line.split(" | ")[0].split(","):
        part = part.strip()
        if "=" in part:
            name, val = part.split("=", 1)
            keep = name in _PARAMS or not _NUM.match(val.strip())
            parts.append(part if keep else name)
        else:
            parts.append(part)
    return ",".join(parts)


def compare(baseline: dict, fresh: dict) -> list[str]:
    """Missing/renamed structure in ``fresh`` relative to ``baseline``."""
    errors = []
    for section, rec in baseline["sections"].items():
        if section not in fresh["sections"]:
            errors.append(f"missing section: {section!r}")
            continue
        want = Counter(line_key(l) for l in rec["lines"])
        have = Counter(line_key(l) for l in fresh["sections"][section]["lines"])
        for key, cnt in (want - have).items():
            errors.append(f"[{section}] missing/renamed key x{cnt}: {key!r}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--out", default="/tmp/bench_now.json")
    ap.add_argument(
        "--reuse", action="store_true",
        help="compare an existing --out file instead of regenerating",
    )
    args = ap.parse_args()

    if not args.reuse or not os.path.exists(args.out):
        cmd = [
            sys.executable, "-m", "benchmarks.run", "--quick",
            "--json", args.out,
        ]
        print("#", " ".join(cmd), flush=True)
        proc = subprocess.run(cmd)
        if proc.returncode != 0:
            print("bench run failed", file=sys.stderr)
            return proc.returncode

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.out) as fh:
        fresh = json.load(fh)

    errors = compare(baseline, fresh)
    if errors:
        print("BENCH STRUCTURE REGRESSION:", file=sys.stderr)
        for e in errors:
            print("  -", e, file=sys.stderr)
        return 1
    n = sum(len(r["lines"]) for r in baseline["sections"].values())
    print(f"bench structure OK: {n} baseline rows all present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
