"""Paper Figs. 7-9: QMC convergence, inverse mapping vs Alias Method.

1-D (Fig. 7): a smooth high-dynamic-range density sampled at 64 steps.
2-D (Figs. 8-9): synthetic HDR environment map, row-then-column inversion.
Metric (Fig. 9): quadratic error sum_i (c_i/N - p_i)^2; also reports the
error RATIO alias/inverse and the extra-samples factor — the paper reports
8x error and 3x samples at 2^26 points on its env map.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.configs.paper_workloads import env_map_2d
from repro.core import (
    build_alias,
    build_forest,
    np_sample_alias,
    quadratic_error,
    sample_forest,
    star_discrepancy_1d,
)
from repro.core.cdf import normalize_weights
from repro.core.lds import hammersley, sobol


def density_1d(n: int = 64) -> np.ndarray:
    x = np.linspace(0, 1, n)
    w = np.exp(8 * np.sin(2 * np.pi * x) ** 2) * (1.2 + np.cos(5 * x))
    return normalize_weights(w + 1e-9)


def run_1d(max_log2: int = 18):
    p = density_1d()
    f = build_forest(jnp.asarray(p), 64)
    at = build_alias(p)
    q, alias = np.asarray(at.q, np.float64), np.asarray(at.alias)
    rows = []
    for lg in range(8, max_log2 + 1, 2):
        n = 1 << lg
        xi = sobol(n, dims=1)[:, 0].astype(np.float32)
        inv = np.asarray(sample_forest(f, jnp.asarray(xi)))
        ali = np_sample_alias(q, alias, xi)
        e_inv = quadratic_error(np.bincount(inv, minlength=64), p)
        e_ali = quadratic_error(np.bincount(ali, minlength=64), p)
        rows.append((n, e_inv, e_ali))
    return rows


def run_2d(max_log2: int = 20, h: int = 128, w: int = 256):
    from repro.core.cdf import np_build_cdf
    from repro.core.forest2d import build_forest_rows, sample_forest_rows

    img = env_map_2d(h, w)
    rowsum = normalize_weights(img.sum(axis=1))
    f_rows = build_forest(jnp.asarray(rowsum), h)
    # all per-row column forests in ONE data-parallel pass (paper Sec. 5)
    col_cdfs = np.stack(
        [np_build_cdf(normalize_weights(img[r] + 1e-18)) for r in range(h)]
    )
    f_cols = build_forest_rows(jnp.asarray(col_cdfs), m=min(w, 256))
    a_rows = build_alias(rowsum)
    a_cols = [build_alias(normalize_weights(img[r] + 1e-18)) for r in range(h)]
    p_flat = (img / img.sum()).ravel()

    out = []
    for lg in range(10, max_log2 + 1, 2):
        n = 1 << lg
        pts = sobol(n, dims=2).astype(np.float32)

        # inverse: monotone row then column (batched multi-row Algorithm 2)
        ri = np.asarray(sample_forest(f_rows, jnp.asarray(pts[:, 0])))
        ci = np.asarray(
            sample_forest_rows(
                f_cols, jnp.asarray(ri, jnp.int32), jnp.asarray(pts[:, 1])
            )
        ).astype(np.int64)
        counts = np.bincount(ri * w + ci, minlength=h * w)
        e_inv = quadratic_error(counts, p_flat)

        # alias: row then column
        qa, aa = np.asarray(a_rows.q, np.float64), np.asarray(a_rows.alias)
        ra = np_sample_alias(qa, aa, pts[:, 0])
        ca = np.empty(n, np.int64)
        for r in np.unique(ra):
            mask = ra == r
            t = a_cols[r]
            ca[mask] = np_sample_alias(
                np.asarray(t.q, np.float64), np.asarray(t.alias), pts[mask, 1]
            )
        counts_a = np.bincount(ra * w + ca, minlength=h * w)
        e_ali = quadratic_error(counts_a, p_flat)
        out.append((n, e_inv, e_ali))
    return out


def run_discrepancy(n: int = 4096):
    """Fig. 1's 'unwarped space' argument, 1-D: star discrepancy of the
    samples mapped back through the CDF (inverse preserves the input's
    discrepancy; alias scrambles it)."""
    p = density_1d()
    f = build_forest(jnp.asarray(p), 64)
    at = build_alias(p)
    xi = sobol(n, dims=1)[:, 0].astype(np.float32)
    d_input = star_discrepancy_1d(xi)
    cdf = np.asarray(f.cdf, np.float64)

    inv = np.asarray(sample_forest(f, jnp.asarray(xi)))
    # unwarp: position of xi inside its interval, mapped back to [0,1)
    width = np.maximum(cdf[inv + 1] - cdf[inv], 1e-30)
    unwarped_inv = cdf[inv] + np.clip((xi - cdf[inv]) / width, 0, 1) * width

    ali = np_sample_alias(np.asarray(at.q, np.float64), np.asarray(at.alias), xi)
    na = len(p)
    frac = xi * na - np.floor(xi * na)
    unwarped_ali = cdf[ali] + frac * np.maximum(cdf[ali + 1] - cdf[ali], 1e-30)

    return {
        "input": d_input,
        "inverse": star_discrepancy_1d(unwarped_inv),
        "alias": star_discrepancy_1d(unwarped_ali),
    }


def main() -> list[str]:
    out = []
    for n, e_inv, e_ali in run_1d():
        out.append(
            f"fig7_1d,n={n},err_inverse={e_inv:.3e},err_alias={e_ali:.3e},"
            f"ratio={e_ali / max(e_inv, 1e-30):.2f}"
        )
    for n, e_inv, e_ali in run_2d():
        out.append(
            f"fig9_2d,n={n},err_inverse={e_inv:.3e},err_alias={e_ali:.3e},"
            f"ratio={e_ali / max(e_inv, 1e-30):.2f}"
        )
    d = run_discrepancy()
    out.append(
        f"fig1_discrepancy,input={d['input']:.4f},inverse={d['inverse']:.4f},"
        f"alias={d['alias']:.4f}"
    )
    return out


if __name__ == "__main__":
    print("\n".join(main()))
