"""Serving-layer payoff of the monotone mapping: best-of-n token sampling.

Draw n tokens from ONE softmax distribution (the paper's shared-distribution
workload, exactly what best-of-n / self-consistency decoding does). With a
stratified (QMC) uniform stream the *monotone* inverse covers the
distribution with O(1/n) marginal error; the Alias Method scrambles the
stream (non-monotone) and PRNG pays O(1/sqrt(n)). Reports the quadratic
marginal error of the sampled token histogram per method.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import build_alias, build_forest, np_sample_alias, quadratic_error, sample_forest
from repro.core.cdf import normalize_weights
from repro.core.lds import sobol, uniform


def run(vocab: int = 2048, n: int = 4096, seed: int = 0):
    rng = np.random.default_rng(seed)
    logits = rng.normal(0, 3.0, vocab)
    p = normalize_weights(np.exp(logits - logits.max()))
    f = build_forest(jnp.asarray(p), vocab)
    at = build_alias(p)
    q, alias = np.asarray(at.q, np.float64), np.asarray(at.alias)

    xi_qmc = sobol(n, dims=1, scramble_seed=seed)[:, 0].astype(np.float32)
    xi_mc = uniform(n, dims=1, seed=seed)[:, 0].astype(np.float32)

    hist = lambda idx: np.bincount(idx, minlength=vocab)
    rows = {
        "inverse_qmc": quadratic_error(
            hist(np.asarray(sample_forest(f, jnp.asarray(xi_qmc)))), p),
        "inverse_prng": quadratic_error(
            hist(np.asarray(sample_forest(f, jnp.asarray(xi_mc)))), p),
        "alias_qmc": quadratic_error(hist(np_sample_alias(q, alias, xi_qmc)), p),
        "alias_prng": quadratic_error(hist(np_sample_alias(q, alias, xi_mc)), p),
    }
    return rows


def main() -> list[str]:
    rows = run()
    base = rows["inverse_qmc"]
    return [
        f"serving_diversity,{k},quad_err={v:.3e},vs_inverse_qmc={v / max(base, 1e-30):.2f}x"
        for k, v in rows.items()
    ]


if __name__ == "__main__":
    print("\n".join(main()))
