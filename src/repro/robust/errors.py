"""Structured error taxonomy for the serving robustness layer.

Every class below subclasses :class:`ValueError` so existing callers (and
tests) that catch ``ValueError`` keep working; new callers can match on the
class or on the machine-readable ``code`` attribute instead of parsing
messages.  The admission codes mirror the ways a weight row can violate the
forest invariants (a monotone CDF needs finite, non-negative mass with a
positive total that survives the f64 normalize):

==================  ==========================================================
code                meaning
==================  ==========================================================
``bad_dtype``       weights not coercible to a real float array
``bad_shape``       weights not a non-empty 1-D vector
``non_finite``      NaN or +/-Inf entries
``negative``        negative entries (even with a positive total — these
                    silently produced a clipped, index-0-biased CDF before)
``zero_total``      all entries zero (or total underflows to zero)
``overflow_on_pad`` entries finite but the f64 total overflows to Inf
``stale_handle``    handle's version does not match the arena row (evicted
                    or recycled)
``quarantined``     handle admitted under the ``quarantine`` policy; serving
                    a placeholder, refusing individual drains
``bad_request``     malformed ``serve.Request`` (submit-time validation)
==================  ==========================================================
"""
from __future__ import annotations

__all__ = [
    "ServingError",
    "AdmissionError",
    "WeightDtypeError",
    "WeightShapeError",
    "NonFiniteWeightError",
    "NegativeWeightError",
    "ZeroTotalError",
    "OverflowOnPadError",
    "StaleHandleError",
    "QuarantinedError",
    "RequestError",
]


class ServingError(ValueError):
    """Base of the serving-robustness taxonomy (a ``ValueError``)."""

    code: str = "serving"


class AdmissionError(ServingError):
    """A weight row violated an admission invariant."""

    code = "admission"


class WeightDtypeError(AdmissionError):
    code = "bad_dtype"


class WeightShapeError(AdmissionError):
    code = "bad_shape"


class NonFiniteWeightError(AdmissionError):
    code = "non_finite"


class NegativeWeightError(AdmissionError):
    code = "negative"


class ZeroTotalError(AdmissionError):
    code = "zero_total"


class OverflowOnPadError(AdmissionError):
    code = "overflow_on_pad"


class StaleHandleError(ServingError):
    """Handle version mismatch: the row was evicted or recycled."""

    code = "stale_handle"


class QuarantinedError(ServingError):
    """Operation refused because the handle is quarantined."""

    code = "quarantined"


class RequestError(ServingError):
    """Malformed ``serve.Request`` caught at submit/admit time."""

    code = "bad_request"


_BY_CODE = {
    "bad_dtype": WeightDtypeError,
    "bad_shape": WeightShapeError,
    "non_finite": NonFiniteWeightError,
    "negative": NegativeWeightError,
    "zero_total": ZeroTotalError,
    "overflow_on_pad": OverflowOnPadError,
    "stale_handle": StaleHandleError,
    "quarantined": QuarantinedError,
    "bad_request": RequestError,
}


def error_for(code: str, msg: str) -> ServingError:
    """Instantiate the taxonomy class for ``code`` with message ``msg``."""
    return _BY_CODE.get(code, ServingError)(msg)
