"""Serving-state snapshot orchestration over :mod:`repro.ckpt`.

Every serving component owns its own exact state pair —
``ForestPool.snapshot()/restore()``, the four QMC stream classes,
``PooledForestSampler``/``SpatialSampler``/``TokenSampler``, and
``ServeEngine`` — all returning nested-dict blobs of numpy arrays and
plain python values. This module is the thin durability layer: it bundles
any set of named components into ONE blob and commits it through the
existing atomic-checkpoint machinery (:func:`repro.ckpt.save_state`:
tmp dir -> fsync -> rename, so a crash mid-save never corrupts the
latest snapshot, and ``latest_step`` auto-resume works unchanged).

    save_serving("/ckpt/serve", step, pool=pool, streams=streams)
    ...
    states, step = load_serving("/ckpt/serve")
    pool = ForestPool.restore(states["pool"])

A killed serving process restored this way produces **bit-identical**
subsequent drains and stream counters (gated by the conformance suite in
``tests/test_serve_robust.py``).
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Any

from repro.ckpt import load_state, save_state

__all__ = ["save_serving", "load_serving"]


def save_serving(path: str | os.PathLike, step: int, **components: Any) -> Path:
    """Snapshot each component (anything with a ``snapshot()`` method, or
    an already-snapshotted dict) and atomically commit the named bundle."""
    blob = {}
    for name, comp in components.items():
        if comp is None:
            blob[name] = None
        elif isinstance(comp, dict):
            blob[name] = comp
        elif hasattr(comp, "snapshot"):
            blob[name] = comp.snapshot()
        else:
            raise TypeError(
                f"component {name!r} has no snapshot() and is not a dict"
            )
    return save_state(path, blob, step)


def load_serving(path: str | os.PathLike, step: int | None = None):
    """Load a :func:`save_serving` bundle; returns ``(states, step)``.
    Each entry is the raw state dict — hand it to the matching class's
    ``restore`` classmethod."""
    return load_state(path, step)
