"""Weight admission: classify and sanitize rows at the serving boundary.

Policy semantics (per pool / per map / per engine):

- ``reject`` (default): any violation raises the matching
  :mod:`repro.robust.errors` class.  Nothing bad ever reaches an arena row.
- ``clamp``: repair in a fixed order — NaN -> 0, +Inf -> f32 max, -Inf -> 0,
  negatives -> 0 — then, if the repaired total is zero (or the finite total
  overflows), substitute the uniform placeholder ``ones(n)``.  The repaired
  row is what gets admitted; the caller learns nothing failed.
- ``quarantine``: admit a uniform placeholder row instead of the bad
  payload and flag the handle; co-tenants in the same packed arena batch are
  untouched and individual drains of the quarantined handle raise
  :class:`~repro.robust.errors.QuarantinedError`.
- ``off``: skip validation entirely (benchmark witness for guard overhead;
  never use in serving).

``bad_dtype``/``bad_shape`` violations raise under every policy — there is
no finite row of the right length to repair toward.
"""
from __future__ import annotations

import numpy as np

from .errors import (
    NegativeWeightError,
    NonFiniteWeightError,
    OverflowOnPadError,
    WeightDtypeError,
    WeightShapeError,
    ZeroTotalError,
    error_for,
)

__all__ = ["POLICIES", "classify_weights", "sanitize_weights", "check_policy"]

POLICIES = ("reject", "clamp", "quarantine", "off")

_F32_MAX = float(np.finfo(np.float32).max)


def check_policy(policy: str) -> str:
    if policy not in POLICIES:
        raise ValueError(f"unknown admission policy {policy!r}; want one of {POLICIES}")
    return policy


def _coerce(w) -> np.ndarray:
    """Coerce to a 1-D non-empty float64 vector or raise (any policy)."""
    try:
        arr = np.asarray(w, dtype=np.float64)
    except (TypeError, ValueError) as e:
        raise WeightDtypeError(f"weights not coercible to float: {e}") from None
    if arr.ndim != 1 or arr.size == 0:
        raise WeightShapeError(
            f"weights must be a non-empty 1-D vector, got shape {arr.shape}"
        )
    return arr


def classify_weights(w, *, allow_zero_total: bool = False):
    """Return ``(arr, code)``: the coerced f64 row and its violation code.

    ``code`` is ``None`` for an admissible row, else one of ``non_finite`` /
    ``negative`` / ``overflow_on_pad`` / ``zero_total``.  Dtype/shape
    violations raise immediately (no policy can repair them).  With
    ``allow_zero_total`` a zero-mass row classifies clean — the spatial map
    treats zero-mass rows as exactly unselectable, not as errors.
    """
    arr = _coerce(w)
    if not np.isfinite(arr).all():
        return arr, "non_finite"
    if (arr < 0.0).any():
        return arr, "negative"
    total = float(np.sum(arr))
    if not np.isfinite(total):
        return arr, "overflow_on_pad"
    if total <= 0.0:
        return arr, None if allow_zero_total else "zero_total"
    return arr, None


def _repair(arr: np.ndarray) -> np.ndarray:
    out = np.where(np.isnan(arr), 0.0, arr)
    out = np.where(out == np.inf, _F32_MAX, out)
    out = np.where(out < 0.0, 0.0, out)
    total = float(np.sum(out))
    if not np.isfinite(total) or total <= 0.0:
        return np.ones(arr.shape[0], dtype=np.float64)
    return out


def sanitize_weights(w, policy: str = "reject", *, allow_zero_total: bool = False):
    """Admit ``w`` under ``policy``; return ``(row_f64, quarantined: bool)``.

    - clean row: returned as-is (f64), ``quarantined=False``;
    - ``reject``: raises the taxonomy class for the violation;
    - ``clamp``: returns the repaired row, ``quarantined=False``;
    - ``quarantine``: returns the uniform placeholder, ``quarantined=True``;
    - ``off``: returns the coerced row unchecked.
    """
    check_policy(policy)
    if policy == "off":
        return _coerce(w), False
    arr, code = classify_weights(w, allow_zero_total=allow_zero_total)
    if code is None:
        return arr, False
    if policy == "reject":
        raise error_for(code, f"weights rejected ({code}) for n={arr.shape[0]} row")
    if policy == "clamp":
        return _repair(arr), False
    return np.ones(arr.shape[0], dtype=np.float64), True


# Re-exported for callers that want to raise a specific class directly.
_ = (
    NonFiniteWeightError,
    NegativeWeightError,
    ZeroTotalError,
    OverflowOnPadError,
)
