"""Serving robustness layer: validated admission, snapshot/restore, and
fault-injection conformance.

Three pieces, threaded through the pool/serve/dist stack:

1. **Validated admission** (:mod:`.errors`, :mod:`.validate`) — a
   structured weight-violation taxonomy (``non_finite`` / ``negative`` /
   ``zero_total`` / ``overflow_on_pad``, every class a ``ValueError``)
   and the per-pool policy ``reject | clamp | quarantine`` enforced at
   the :class:`~repro.pool.ForestPool` /
   :class:`~repro.spatial.Map2DSampler` /
   :class:`~repro.serve.ServeEngine` boundary.
2. **Snapshot/restore** (:mod:`.snapshot`) — every serving component
   exposes an exact ``snapshot()``/``restore()`` state pair;
   :func:`save_serving`/:func:`load_serving` commit bundles atomically
   through :mod:`repro.ckpt`, and a killed process resumes with
   bit-identical drains and stream counters.
3. **Invariant checks + chaos harness** (:mod:`.verify`, :mod:`.faults`)
   — ``verify_forest``/``verify_alias``/``verify_pool`` structural
   self-checks, and a :class:`~repro.robust.faults.FaultPlan` harness
   that injects corrupted submissions, stale handles, kills, and mesh
   shrinks, asserting co-tenant bit-isolation throughout.

``faults`` is imported lazily (``from repro.robust.faults import ...``)
because it reaches back into :mod:`repro.pool`, which itself imports
this package's taxonomy.
"""
from .errors import (
    AdmissionError,
    NegativeWeightError,
    NonFiniteWeightError,
    OverflowOnPadError,
    QuarantinedError,
    RequestError,
    ServingError,
    StaleHandleError,
    WeightDtypeError,
    WeightShapeError,
    ZeroTotalError,
)
from .snapshot import load_serving, save_serving
from .validate import POLICIES, classify_weights, sanitize_weights
from .verify import verify_alias, verify_forest, verify_pool

__all__ = [
    "AdmissionError",
    "NegativeWeightError",
    "NonFiniteWeightError",
    "OverflowOnPadError",
    "QuarantinedError",
    "RequestError",
    "ServingError",
    "StaleHandleError",
    "WeightDtypeError",
    "WeightShapeError",
    "ZeroTotalError",
    "POLICIES",
    "classify_weights",
    "sanitize_weights",
    "verify_alias",
    "verify_forest",
    "verify_pool",
    "load_serving",
    "save_serving",
]
