"""Structural self-checks for forests, alias tables, and pool arenas.

These are the on-demand / post-restore invariant checkers of the
robustness layer: lighter than :func:`repro.core.forest.validate_forest`
(which walks every tree node recursively in Python) — vectorized numpy
checks of exactly the invariants sampling correctness rests on:

- ``verify_forest``: the CDF is a finite monotone partition of [0, 1]
  with exact endpoints; guide cells cover the interval list disjointly
  (``cell_first`` nondecreasing, in range); child refs are in range.
- ``verify_alias``: split points ``q`` in [0, 1]; alias targets in range;
  and **mass conservation** — the implied per-cell probability
  ``(q_i + sum_{j: alias_j == i} (1 - q_j)) / n`` matches the normalized
  weights within an ulp-scale tolerance.
- ``verify_pool``: free-list / version / shadow-copy consistency of every
  arena (no leaked or double-freed rows), then the per-row forest / alias
  checks against each tenant's raw-weight shadow.

Each returns a list of violation strings (empty = healthy); pass
``raise_on_error=True`` to turn violations into a ``ValueError``.
"""
from __future__ import annotations

import numpy as np

from repro.core.cdf import build_cdf, normalize_weights

__all__ = ["verify_forest", "verify_alias", "verify_pool"]


def _fail(errors: list[str], raise_on_error: bool):
    if errors and raise_on_error:
        raise ValueError("; ".join(errors))
    return errors


def verify_forest(forest, weights=None, *, raise_on_error: bool = False):
    """Check one (padded) forest's structural invariants.

    ``forest`` is a :class:`~repro.core.forest.RadixForest` (or any object
    with ``cdf``/``left``/``right``/``cell_first`` fields). With
    ``weights`` (the padded, normalized float32 row the forest was built
    from) the CDF is additionally checked bit-level against a recomputed
    ``build_cdf`` — the strongest witness that no corruption reached the
    arena row.
    """
    errors: list[str] = []
    cdf = np.asarray(forest.cdf, np.float32)
    n = cdf.shape[0] - 1
    if not np.isfinite(cdf).all():
        errors.append("cdf has non-finite entries")
    else:
        if cdf[0] != 0.0:
            errors.append(f"cdf[0] = {cdf[0]!r}, want exactly 0.0")
        if cdf[-1] != 1.0:
            errors.append(f"cdf[-1] = {cdf[-1]!r}, want exactly 1.0")
        if (np.diff(cdf) < 0).any():
            errors.append("cdf not monotone nondecreasing")
    cf = np.asarray(forest.cell_first, np.int64)
    if (np.diff(cf) < 0).any():
        errors.append("cell_first not nondecreasing (cells overlap)")
    if cf.size and (cf[0] < 0 or cf[-1] > n):
        errors.append(f"cell_first out of range [0, {n}]")
    for name in ("left", "right"):
        ch = np.asarray(getattr(forest, name), np.int64)
        # >= 0: internal node id; < 0: ~interval leaf ref.
        leaf = np.where(ch < 0, ~ch, 0)
        node = np.where(ch >= 0, ch, 0)
        if (leaf >= n).any() or (node >= n).any():
            errors.append(f"{name} child refs out of range for n={n}")
    if weights is not None and not errors:
        want = np.asarray(build_cdf(np.asarray(weights, np.float32)))
        if want.shape != cdf.shape or not np.array_equal(
            want.view(np.uint32), cdf.view(np.uint32)
        ):
            errors.append("cdf bits do not match build_cdf(weights)")
    return _fail(errors, raise_on_error)


def verify_alias(table, weights=None, *, raise_on_error: bool = False):
    """Check one (padded) packed alias table; with ``weights`` (the padded
    normalized row) also check mass conservation within ulp bounds."""
    errors: list[str] = []
    q = np.asarray(table.q, np.float64)
    alias = np.asarray(table.alias, np.int64)
    n = q.shape[0]
    if not np.isfinite(q).all() or (q < 0.0).any() or (q > 1.0).any():
        errors.append("alias split points q outside [0, 1]")
    if (alias < 0).any() or (alias >= n).any():
        errors.append(f"alias targets out of range [0, {n})")
    if weights is not None and not errors:
        w = np.asarray(weights, np.float64)
        # implied mass: own kept fraction + every donation received
        p = q + np.bincount(alias, weights=1.0 - q, minlength=n)
        p /= n
        tol = 16.0 * np.finfo(np.float32).eps * max(n, 1)
        if np.abs(p - w / max(w.sum(), 1e-300)).max() > tol:
            errors.append(
                f"alias table does not conserve mass (max err "
                f"{np.abs(p - w / max(w.sum(), 1e-300)).max():.3e} > {tol:.3e})"
            )
    return _fail(errors, raise_on_error)


def _verify_arena(kind: str, size: int, ar, errors: list[str]) -> None:
    free = list(ar.free)
    if len(set(free)) != len(free):
        errors.append(f"{kind}[{size}]: duplicate rows in free list")
    occupied = set(ar.raw.keys())
    allr = set(range(ar.rows))
    if not set(free).issubset(allr) or not occupied.issubset(allr):
        errors.append(f"{kind}[{size}]: row index out of range")
    if set(free) & occupied:
        errors.append(f"{kind}[{size}]: free rows also occupied")
    if (set(free) | occupied) != allr:
        errors.append(f"{kind}[{size}]: leaked rows (neither free nor occupied)")
    for row in occupied:
        nt = int(ar.n_true[row])
        if not (0 < nt <= ar.size):
            errors.append(f"{kind}[{size}] row {row}: bad n_true {nt}")
        if len(ar.raw[row]) != nt:
            errors.append(f"{kind}[{size}] row {row}: raw shadow length mismatch")
    if (np.asarray(ar.versions) < 0).any():
        errors.append(f"{kind}[{size}]: negative version counter")


def verify_pool(pool, *, deep: bool = True, raise_on_error: bool = False):
    """Check every arena of a :class:`~repro.pool.arena.ForestPool`.

    Always checks the slot machine (free list / version / shadow-copy
    consistency); with ``deep`` also re-derives each occupied row's padded
    normalized weights from the raw shadow and runs the per-row forest /
    alias structural checks against them.
    """
    errors: list[str] = []
    for size, sc in sorted(pool.classes.items()):
        _verify_arena("forest", size, sc, errors)
        if not deep or sc.forest is None:
            continue
        cdf = np.asarray(sc.forest.cdf)
        cf = np.asarray(sc.forest.cell_first)
        left = np.asarray(sc.forest.left)
        right = np.asarray(sc.forest.right)
        for row in sorted(sc.raw):
            view = _RowView(cdf[row], left[row], right[row], cf[row])
            padded = np.pad(
                normalize_weights(sc.raw[row]), (0, size - len(sc.raw[row]))
            )
            for e in verify_forest(view, padded):
                errors.append(f"forest[{size}] row {row}: {e}")
    for size, ar in sorted(pool.alias_classes.items()):
        _verify_arena("alias", size, ar, errors)
        if not deep or ar.table is None:
            continue
        q = np.asarray(ar.table.q)
        alias = np.asarray(ar.table.alias)
        for row in sorted(ar.raw):
            view = _AliasView(q[row], alias[row])
            padded = np.pad(
                normalize_weights(ar.raw[row]), (0, size - len(ar.raw[row]))
            )
            for e in verify_alias(view, padded):
                errors.append(f"alias[{size}] row {row}: {e}")
    return _fail(errors, raise_on_error)


class _RowView:
    def __init__(self, cdf, left, right, cell_first):
        self.cdf, self.left, self.right = cdf, left, right
        self.cell_first = cell_first


class _AliasView:
    def __init__(self, q, alias):
        self.q, self.alias = q, alias
