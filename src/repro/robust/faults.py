"""Fault-injection chaos harness for the serving pool.

A :class:`FaultPlan` schedules adversarial events against a churning
multi-tenant :class:`~repro.pool.ForestPool`; :func:`run_chaos` executes
the plan against a **twin-pool oracle**: a chaos pool that sees every
fault and a clean pool that never does, both serving the same co-tenant
schedule. After every step the harness asserts the robustness contract:

- every fault is contained — caught as a structured
  :mod:`repro.robust.errors` class (or absorbed by the clamp/quarantine
  policy), never an unhandled crash;
- co-tenants are never corrupted — their drains stay **bit-identical**
  to the clean pool's (the pool that never saw the bad input);
- :func:`repro.robust.verify.verify_pool` passes after every scenario.

Fault kinds: ``bad_insert`` / ``bad_update`` (NaN / Inf / negative /
all-zero / denormal weight rows), ``stale_drain`` (drain through an
evicted handle), ``double_evict``, and ``kill`` (invokes ``kill_hook`` —
the subprocess conformance test passes ``os._exit`` there to die
mid-churn; in-process runs just record it).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .errors import ServingError
from .verify import verify_pool

__all__ = ["Fault", "FaultPlan", "run_chaos"]

_BAD_ROWS = {
    "nan": lambda n: np.where(np.arange(n) == 1, np.nan, 1.0),
    "inf": lambda n: np.where(np.arange(n) == 0, np.inf, 1.0),
    "neg": lambda n: np.where(np.arange(n) == 2 % n, -1.0, 2.0),
    "zero": lambda n: np.zeros(n),
    "denormal": lambda n: np.full(n, 5e-324),
}


@dataclasses.dataclass(frozen=True)
class Fault:
    step: int
    kind: str        # bad_insert | bad_update | stale_drain | double_evict | kill
    flavor: str = "nan"  # which _BAD_ROWS generator (weight faults only)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    faults: tuple

    @classmethod
    def default(cls, steps: int = 24, seed: int = 0) -> "FaultPlan":
        """A dense pseudo-random schedule touching every fault kind and
        every adversarial weight flavor within ``steps`` churn steps."""
        rng = np.random.default_rng(seed)
        kinds = ["bad_insert", "bad_update", "stale_drain", "double_evict"]
        flavors = list(_BAD_ROWS)
        faults = []
        for step in range(steps):
            if rng.random() < 0.5:
                faults.append(Fault(
                    step=step,
                    kind=kinds[int(rng.integers(len(kinds)))],
                    flavor=flavors[int(rng.integers(len(flavors)))],
                ))
        return cls(faults=tuple(faults))

    def at(self, step: int):
        return [f for f in self.faults if f.step == step]


def run_chaos(plan: FaultPlan, *, steps: int = 24, policy: str = "quarantine",
              seed: int = 0, n_tenants: int = 6, kill_hook=None) -> dict:
    """Execute ``plan`` against the twin-pool oracle; returns a report:

    ``drains_equal`` — co-tenant drains stayed bit-identical to the clean
    pool on every step; ``verify_errors`` — accumulated
    :func:`verify_pool` violations (empty = healthy); ``caught`` — the
    ``(step, kind, code)`` of every structured error a fault produced;
    ``injected`` — fault count; ``quarantined`` — final quarantine count.
    """
    from repro.pool import ForestPool  # lazy: robust.errors has no pool dep

    rng = np.random.default_rng(seed)
    chaos = ForestPool(policy=policy)
    clean = ForestPool(policy="reject")
    sizes = [int(rng.integers(3, 20)) for _ in range(n_tenants)]
    weights = [rng.random(n) + 1e-3 for n in sizes]
    methods = ["forest" if i % 2 == 0 else "alias" for i in range(n_tenants)]
    ch = chaos.insert_many(weights, method=methods)
    cl = clean.insert_many(weights, method=methods)

    report = dict(drains_equal=True, verify_errors=[], caught=[],
                  injected=0, kills=0)
    for step in range(steps):
        # co-tenant churn: the SAME clean update against both pools
        t = int(rng.integers(n_tenants))
        upd = rng.random(sizes[t]) + 1e-3
        chaos.update_weights(ch[t], upd)
        clean.update_weights(cl[t], upd)

        for f in plan.at(step):
            report["injected"] += 1
            try:
                if f.kind == "bad_insert":
                    n = int(rng.integers(3, 12))
                    chaos.insert(_BAD_ROWS[f.flavor](n))
                elif f.kind == "bad_update":
                    v = int(rng.integers(n_tenants))
                    chaos.update_weights(ch[v], _BAD_ROWS[f.flavor](sizes[v]))
                    # keep the twins in sync: mirror whatever the policy
                    # admitted (clean never sees the bad row; restore the
                    # tenant's good weights in both pools)
                    good = rng.random(sizes[v]) + 1e-3
                    chaos.update_weights(ch[v], good)
                    clean.update_weights(cl[v], good)
                elif f.kind == "stale_drain":
                    tmp = chaos.insert(rng.random(5) + 1e-3)
                    chaos.evict(tmp)
                    chaos.sample([tmp], np.asarray([0.5], np.float32))
                elif f.kind == "double_evict":
                    tmp = chaos.insert(rng.random(5) + 1e-3)
                    chaos.evict(tmp)
                    chaos.evict(tmp)
                elif f.kind == "kill":
                    report["kills"] += 1
                    if kill_hook is not None:
                        kill_hook(step)
                else:
                    raise ValueError(f"unknown fault kind {f.kind!r}")
            except ServingError as e:
                report["caught"].append((step, f.kind, e.code))
            except ValueError as e:
                report["caught"].append((step, f.kind, str(e)))

        # co-tenant conformance drain: same uniforms, both pools
        xi = rng.random(2 * n_tenants).astype(np.float32)
        hs_c = [ch[i % n_tenants] for i in range(len(xi))]
        hs_k = [cl[i % n_tenants] for i in range(len(xi))]
        got = chaos.sample(hs_c, xi)
        want = clean.sample(hs_k, xi)
        if not np.array_equal(got, want):
            report["drains_equal"] = False
        report["verify_errors"].extend(verify_pool(chaos))

    report["quarantined"] = len(chaos.quarantined)
    return report
