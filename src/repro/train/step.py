"""Jittable train/serve steps — the units the dry-run lowers and compiles.

``make_train_step``: loss -> grad -> AdamW, with optional microbatch
gradient accumulation (``lax.scan`` over microbatches; overlaps the implicit
DP gradient reduction of microbatch i with the compute of i+1 under XLA's
latency-hiding scheduler) and optional bf16 gradient compression of the
accumulator (halves accumulation memory traffic + the cross-pod all-reduce
payload; error feedback not needed at bf16 — documented in DESIGN.md §6).

``make_serve_step``: one decode token through the cached stack, then the
paper's sampler: fused softmax->CDF + tiled inverse (kernels), or the
pure-jnp path for dry-runs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import decode_step as model_decode
from repro.models import loss_fn
from repro.models.config import ModelConfig

from .optimizer import AdamWConfig, OptState, apply_updates


def make_train_step(
    cfg: ModelConfig,
    oc: AdamWConfig,
    remat: str = "dots",
    microbatches: int = 1,
    grad_dtype: str = "float32",
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch tensors are (B, ...); with microbatches=k they are reshaped to
    (k, B/k, ...) and accumulated.
    """

    gdt = jnp.bfloat16 if grad_dtype == "bfloat16" else jnp.float32

    def loss_wrapped(params, batch):
        return loss_fn(params, cfg, batch, remat=remat)

    def train_step(params, opt_state: OptState, batch: dict):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_wrapped, has_aux=True)(
                params, batch
            )
        else:
            mb = {
                k: v.reshape((microbatches, v.shape[0] // microbatches) + v.shape[1:])
                for k, v in batch.items()
            }

            def body(acc, micro):
                (l, m), g = jax.value_and_grad(loss_wrapped, has_aux=True)(
                    params, micro
                )
                g = jax.tree.map(lambda a, b: a + b.astype(gdt), acc[0], g)
                return (g, acc[1] + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}

        params, opt_state, om = apply_updates(oc, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def make_serve_step(cfg: ModelConfig, use_pallas: bool = False, temperature: float = 1.0):
    """Returns serve_step(params, cache, token, pos, xi[, enc_out])
    -> (next_token (B,), cache). xi: per-slot uniforms (B,) — QMC streams
    from the serving scheduler keep the monotone warp stratified."""

    def serve_step(params, cache, token, pos, xi, enc_out=None):
        logits, cache = model_decode(params, cfg, cache, token, pos, enc_out)
        cdf = ops.fused_cdf(logits / temperature, softmax=True, use_pallas=use_pallas)
        nxt = ops.sample_rows(cdf, xi[:, None], use_pallas=use_pallas)[:, 0]
        return nxt.astype(jnp.int32), cache

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    from repro.models import prefill as model_prefill

    def prefill_step(params, batch):
        logits, cache, enc_out = model_prefill(params, cfg, batch, max_seq=max_seq)
        return logits, cache, enc_out

    return prefill_step
