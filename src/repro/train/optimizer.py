"""AdamW + cosine schedule, implemented from scratch as pytree transforms.

Optimizer state shards exactly like the parameters (ZeRO: m/v inherit the
FSDP+TP PartitionSpecs), so memory per device is (p + m + v) / n_shards.
``opt_dtype='bfloat16'`` halves m/v for the trillion-parameter configs
(documented memory/precision tradeoff in EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    opt_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def schedule(c: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * (c.min_lr_ratio + (1 - c.min_lr_ratio) * cos)


def init_opt(c: AdamWConfig, params: Any) -> OptState:
    dt = jnp.bfloat16 if c.opt_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(c: AdamWConfig, params: Any, grads: Any, st: OptState):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = st.step + 1
    lr = schedule(c, step)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * c.b1 + g * (1 - c.b1)
        v32 = v.astype(jnp.float32) * c.b2 + g * g * (1 - c.b2)
        u = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + c.eps)
        decay = c.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (u + decay)
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(st.m)
    flat_v = jax.tree.leaves(st.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
