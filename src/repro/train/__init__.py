from .optimizer import AdamWConfig, OptState, apply_updates, init_opt
from .step import make_prefill_step, make_serve_step, make_train_step
from .trainer import TrainConfig, Trainer

__all__ = [
    "AdamWConfig", "OptState", "apply_updates", "init_opt",
    "make_prefill_step", "make_serve_step", "make_train_step",
    "TrainConfig", "Trainer",
]
