"""Training driver: deterministic data, checkpoint/restart, failure injection.

The restart contract: batches are pure functions of (seed, step) and the
checkpoint stores (params, opt_state, step), so kill-at-any-step + resume
reproduces the exact same trajectory — asserted bitwise in
tests/test_fault_tolerance.py. This is the single-process core of the
multi-pod story: on a real cluster every host runs this same loop under
jax.distributed, checkpoints go to shared storage, and a failed pod rejoins
by auto-resume (elastic re-shard handled by ckpt.restore's device_put).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, latest_step
from repro.data.mixture import MixtureSampler
from repro.data.pipeline import make_batch
from repro.models import init_params
from repro.models.config import ModelConfig

from .optimizer import AdamWConfig, init_opt
from .step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    seed: int = 0
    ckpt_dir: str = "checkpoints/run"
    ckpt_every: int = 25
    keep: int = 3
    log_every: int = 10
    remat: str = "none"
    microbatches: int = 1
    mixture_weights: tuple = (0.5, 0.25, 0.125, 0.125)


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig,
                 oc: AdamWConfig | None = None,
                 fail_at_step: int | None = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg = cfg
        self.tc = tc
        self.oc = oc or AdamWConfig(total_steps=tc.steps, warmup_steps=max(tc.steps // 20, 1))
        self.fail_at_step = fail_at_step
        self.log = log_fn
        self.mixture = MixtureSampler(tc.mixture_weights, seed=tc.seed)
        self.step_fn = jax.jit(
            make_train_step(cfg, self.oc, remat=tc.remat, microbatches=tc.microbatches),
            donate_argnums=(0, 1),
        )
        self.mgr = CheckpointManager(tc.ckpt_dir, keep=tc.keep)

    def init_state(self):
        params = init_params(jax.random.PRNGKey(self.tc.seed), self.cfg)
        opt = init_opt(self.oc, params)
        return params, opt

    def run(self) -> dict[str, Any]:
        params, opt = self.init_state()
        start = 0
        if latest_step(self.tc.ckpt_dir) is not None:
            (params, opt), start = self.mgr.restore_latest((params, opt))
            start = int(np.asarray(opt.step))
            self.log(f"resumed from step {start}")
        metrics_hist = []
        t0 = time.time()
        for step in range(start, self.tc.steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch_np = make_batch(
                self.cfg, step, self.tc.global_batch, self.tc.seq_len,
                mixture=self.mixture, seed=self.tc.seed,
            )
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt, m = self.step_fn(params, opt, batch)
            if step % self.tc.log_every == 0 or step == self.tc.steps - 1:
                loss = float(m["loss"])
                self.log(
                    f"step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(m['grad_norm']):.3f} "
                    f"lr {float(m['lr']):.2e} "
                    f"({(time.time() - t0):.1f}s)"
                )
                metrics_hist.append({"step": step, "loss": loss})
            if (step + 1) % self.tc.ckpt_every == 0 or step == self.tc.steps - 1:
                self.mgr.save((params, opt), step + 1)
        self.mgr.wait()
        return {
            "params": params,
            "opt": opt,
            "metrics": metrics_hist,
            "final_loss": metrics_hist[-1]["loss"] if metrics_hist else None,
        }
