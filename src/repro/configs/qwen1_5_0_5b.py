"""Qwen1.5-0.5B: dense, QKV bias, tied embeddings.

24L d_model=1024 16H (MHA kv=16) d_ff=2816 vocab=151936 [hf:Qwen/Qwen1.5-0.5B].
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
)

REDUCED = reduced(CONFIG)
