"""Granite-3 8B: dense, GQA kv=8.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155 [hf:ibm-granite].
Note vocab 49155 is odd (3 x 16385): exercises GSPMD uneven vocab sharding.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab=49155,
)

REDUCED = reduced(CONFIG)
