"""Whisper-small: encoder-decoder, conv audio frontend stubbed.

12L (x2: 12 enc + 12 dec) d_model=768 12H d_ff=3072 vocab=51865
[arXiv:2212.04356]. input_specs() supplies precomputed frame embeddings
(B, S_enc, d_model) — the conv1d stack is a stub per the assignment.
Divergence noted in DESIGN.md: RoPE replaces learned/sinusoidal positions.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    encoder_layers=12,
    cross_attention=True,
    frontend="audio",
)

REDUCED = reduced(CONFIG)
