"""Architecture registry: the 10 assigned configs + paper-native workloads.

Every module defines ``CONFIG`` (full scale, dry-run only) and the registry
offers ``get(name)`` / ``get_reduced(name)`` (CPU smoke scale).

Import hygiene: this module imports **nothing** from ``repro.models`` at
module scope — config lookups must keep working even when a heavyweight
subsystem (models / dist / kernels) is broken, so that one bad import fails
only its own tests instead of cascading through every consumer of the
registry (``ModelConfig``/``reduced`` are fetched lazily inside ``get`` /
``get_reduced`` / ``__getattr__``).
"""
from __future__ import annotations

import importlib
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # annotation-only; not imported at runtime
    from repro.models.config import ModelConfig

ARCHS = [
    "jamba_1_5_large_398b",
    "llama4_maverick_400b_a17b",
    "kimi_k2_1t_a32b",
    "whisper_small",
    "internvl2_76b",
    "xlstm_1_3b",
    "qwen1_5_0_5b",
    "stablelm_3b",
    "qwen3_4b",
    "granite_3_8b",
]

_ALIAS = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "whisper-small": "whisper_small",
    "internvl2-76b": "internvl2_76b",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "stablelm-3b": "stablelm_3b",
    "qwen3-4b": "qwen3_4b",
    "granite-3-8b": "granite_3_8b",
}


def canonical(name: str) -> str:
    return _ALIAS.get(name, name)


def get(name: str) -> "ModelConfig":
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_reduced(name: str) -> "ModelConfig":
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    if hasattr(mod, "REDUCED"):
        return mod.REDUCED
    from repro.models.config import reduced

    return reduced(mod.CONFIG)


def all_configs() -> dict:
    return {a: get(a) for a in ARCHS}


def __getattr__(name: str):  # back-compat: configs.ModelConfig / configs.reduced
    if name in ("ModelConfig", "reduced"):
        from repro.models import config as _c

        return getattr(_c, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
