"""Llama-4 Maverick (assignment numbers verbatim): MoE 128e top-1.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-*]. Early-fusion multimodal in the original; the
assignment exercises the text backbone. Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    block_pattern=("attn",),
    mlp_pattern=("moe",),
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    moe_ff=8192,
)

REDUCED = reduced(CONFIG)
