"""xLSTM-1.3B: alternating mLSTM / sLSTM blocks (1:1 at this scale).

48L d_model=2048 4H d_ff=0 (projections live inside the blocks) vocab=50304
[arXiv:2405.04517]. Fully recurrent -> sub-quadratic -> runs long_500k.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    mlp_pattern=("none",),
    mlstm_chunk=128,
)

REDUCED = reduced(CONFIG)
