"""The paper's own sampling workloads (Table 1 / Fig. 12 distributions).

Sizes n, m are not stated in the paper; defaults chosen to reproduce the
magnitude of Table 1 (see EXPERIMENTS.md §Paper). All weights normalized in
float64 on host (high dynamic range overflows float32 pre-normalization).
"""
from __future__ import annotations

import numpy as np

from repro.core.cdf import normalize_weights


def dist_i20(n: int = 256) -> np.ndarray:
    return normalize_weights(np.arange(1, n + 1, dtype=np.float64) ** 20)


def dist_mod32(n: int = 256) -> np.ndarray:
    return normalize_weights((np.arange(n) % 32 + 1.0) ** 25)


def dist_mod64(n: int = 256) -> np.ndarray:
    return normalize_weights((np.arange(n) % 64 + 1.0) ** 35)


def dist_4spikes(n: int = 256) -> np.ndarray:
    w = np.full(n, 0.2 / (n - 4), np.float64)
    idx = np.linspace(0, n, 5, dtype=np.int64)[:-1] + n // 8
    w[idx] = 0.2
    return normalize_weights(w)


def env_map_2d(h: int = 256, w: int = 512, seed: int = 0) -> np.ndarray:
    """Synthetic HDR environment map: smooth base + bright sun spots
    (stands in for the paper's copyrighted openfootage.net image)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:h, 0:w]
    base = 0.3 + 0.2 * np.sin(xx / w * 2 * np.pi) * np.cos(yy / h * np.pi)
    img = base
    for _ in range(6):
        cy, cx = rng.integers(0, h), rng.integers(0, w)
        amp = 10 ** rng.uniform(1.5, 4)
        sig = rng.uniform(1.0, 6.0)
        img = img + amp * np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * sig**2)))
    return (img / img.sum()).astype(np.float64)


TABLE1 = {
    "i^20": dist_i20,
    "(i mod 32 + 1)^25": dist_mod32,
    "(i mod 64 + 1)^35": dist_mod64,
    "4 spikes": dist_4spikes,
}
