"""Kimi K2: trillion-parameter MoE (DeepSeek-V3-style fine-grained experts).

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8 + 1 shared [Kimi K2 paper table]. First layer dense in
the original; assignment numbers applied uniformly. ~1.03T total params.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    block_pattern=("attn",),
    mlp_pattern=("moe",),
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    moe_ff=2048,
    # 61 is prime: period must divide n_layers -> period 1.
)

REDUCED = reduced(CONFIG)
