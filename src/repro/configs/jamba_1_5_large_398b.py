"""Jamba-1.5-Large: hybrid Mamba+attention 1:7, MoE 16e top-2 every 2nd layer.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887].
Period-8 super-block: attention at position 4 (1 attn : 7 mamba), MoE on odd
positions (matches the published 398B total / 94B active; see DESIGN.md §5).
Sub-quadratic (mostly-SSM) -> runs long_500k.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    mlp_pattern=("dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe"),
    n_experts=16,
    top_k=2,
    ssm_state=16,
    ssm_expand=2,
)

REDUCED = reduced(CONFIG)
