"""InternVL2-76B backbone (InternLM2-like LLM; InternViT frontend stubbed).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2404.16821].
frontend="embed": input_specs() supplies mixed text+patch embeddings
(B, S, d_model) directly; labels mask the patch positions with -1.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    block_pattern=("attn",),
    mlp_pattern=("dense",),
    frontend="embed",
)

REDUCED = reduced(CONFIG)
