"""Scoped tracing hints: ZeRO-3 gather-on-use and Megatron-SP residuals.

``repro.models.model`` calls :func:`gather_params` / :func:`act_seq`
unconditionally. The contract:

* **Outside** a :func:`sharding_hints` context both functions return their
  argument *unchanged* (the very same object — not a copy, not an identity
  op in the jaxpr). Hints-free execution is therefore bit-identical to a
  model that never heard of this module (tested by
  ``tests/test_dist.py::test_hints_noop_bitwise``).
* **Inside** the context they insert ``with_sharding_constraint``s:
  ``gather_params`` re-constrains each parameter leaf to its policy spec
  *minus the FSDP axes* (params stay TP-sharded but are gathered across the
  ZeRO-3 axes right at the point of use, letting XLA overlap the gather with
  the previous layer); ``act_seq`` constrains the (B, S, D) residual stream
  to be sequence-sharded over ``Policy.sp`` (Megatron sequence parallelism:
  norms and elementwise work run on S/sp_size tokens per device).

Cache-key caveat: the hints are read at *trace* time. An entry point must be
first traced (``jit(...).lower`` or first call) inside the context for the
hints to take effect — re-calling an already-traced jit under different
hints returns the cached executable. The dry-run launcher compiles one cell
per process, which guarantees this; tests build fresh ``jax.jit`` objects.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

from .sharding import Policy, param_specs, _entry, _sanitize

_CURRENT: "Hints | None" = None


@dataclasses.dataclass(frozen=True)
class Hints:
    """What to constrain while tracing under ``sharding_hints``.

    ``mesh`` may be omitted: it is resolved from the ambient ``with mesh:``
    context at trace time (the dry-run always runs inside one).
    """

    policy: Policy
    gather_weights: bool = False
    seq_shard: bool = False
    mesh: Any = None


@contextlib.contextmanager
def sharding_hints(hints: Hints):
    """Activate ``hints`` for every model traced inside the block."""
    global _CURRENT
    prev, _CURRENT = _CURRENT, hints
    try:
        yield hints
    finally:
        _CURRENT = prev


def current_hints() -> Hints | None:
    return _CURRENT


def _resolve_mesh(h: Hints):
    if h.mesh is not None:
        return h.mesh
    try:  # ambient `with mesh:` context (jax keeps it in thread resources)
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def gather_params(tree: Any) -> Any:
    """ZeRO-3 gather-on-use. Identity (same object) without active hints."""
    h = _CURRENT
    if h is None or not h.gather_weights:
        return tree
    mesh = _resolve_mesh(h)
    if mesh is None:
        return tree
    import jax
    from jax.sharding import NamedSharding

    # Gathered view: same spec tree with the FSDP axes dropped (TP survives).
    pol = dataclasses.replace(h.policy, fsdp=())
    specs = param_specs(tree, pol, dict(mesh.shape))
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree,
        specs,
    )


def act_seq(x: Any) -> Any:
    """Megatron-SP residual constraint. Identity without active hints."""
    h = _CURRENT
    if h is None or not h.seq_shard:
        return x
    mesh = _resolve_mesh(h)
    if mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding

    pol = h.policy
    dp = None if pol.shard_seq and not pol.dp else _entry(pol.dp)
    spec = (dp, _entry(pol.sp)) + (None,) * (x.ndim - 2)
    s = _sanitize(spec[: x.ndim], x.shape, dict(mesh.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
