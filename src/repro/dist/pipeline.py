"""GPipe microbatch pipeline over ``lax.ppermute`` (paper-era classic).

``gpipe(block, mesh, axis)`` turns a per-layer ``block(W, h) -> h`` into a
pipelined ``f(Ws, xs)`` where ``Ws`` stacks the L layer params on axis 0 and
``xs`` stacks M microbatches on axis 0. The mesh axis ``axis`` (size S)
carries the pipeline: each stage owns L/S consecutive layers (``shard_map``
splits ``Ws``), microbatches stream through the stages, and stage boundaries
are a single ring ``ppermute`` per tick.

Schedule: T = M + S - 1 ticks; at tick ``t`` stage ``s`` runs microbatch
``t - s`` through its local layers (bubble fraction (S-1)/T, the GPipe
figure). Stage 0 ingests ``xs[t]``; the last stage accumulates its output
into slot ``t - (S-1)``; a final ``psum`` over the pipeline axis replicates
the result (only the last stage contributes non-zeros, so the sum is exact).

Guarantees (asserted by ``test_gpipe_matches_sequential``):

* **Matches sequential execution exactly** — every microbatch sees the same
  per-layer op sequence as a plain loop; no re-ordering, no rescaling.
* **Differentiable** — ``ppermute``/``psum``/``where`` all have transposes,
  so ``jax.grad`` flows through the schedule (backward runs the reverse
  permutes — the classic GPipe backward bubble).

Mesh axes not named ``axis`` are left unmentioned in the ``shard_map`` specs
(replicated), so a (pod, data) mesh pipelines over pods while data
parallelism proceeds untouched inside each stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe(block, mesh, axis: str):
    """Build the pipelined callable. ``block(W, h) -> h`` must be shape
    preserving; ``Ws.shape[0]`` must be divisible by ``mesh.shape[axis]``."""
    S = int(mesh.shape[axis])
    ring = [(i, (i + 1) % S) for i in range(S)]

    def stage(ws, xs):
        # ws: (L/S, ...) this stage's layers; xs: (M, mb, D) full stream.
        M = xs.shape[0]
        idx = jax.lax.axis_index(axis)

        def local(h):
            return jax.lax.scan(lambda c, W: (block(W, c), None), h, ws)[0]

        out = jnp.zeros_like(xs)
        carry = jnp.zeros(xs.shape[1:], xs.dtype)
        for t in range(M + S - 1):
            inp = jnp.where(idx == 0, xs[min(t, M - 1)], carry)
            y = local(inp)
            carry = jax.lax.ppermute(y, axis, ring)
            j = t - (S - 1)
            if 0 <= j < M:  # last stage finished microbatch j this tick
                out = out.at[j].add(jnp.where(idx == S - 1, y, jnp.zeros_like(y)))
        # Only stage S-1 wrote non-zeros -> psum replicates exactly.
        return jax.lax.psum(out, axis)

    def pipelined(Ws, xs):
        L = Ws.shape[0]
        if L % S != 0:
            raise ValueError(f"layers ({L}) must divide over pipeline axis ({S})")
        return shard_map(
            stage,
            mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
            check_rep=False,
        )(Ws, xs)

    return pipelined
