"""Distribution layer: sharding policies, tracing hints, pipeline schedule,
and gradient compression.

Submodules (imported lazily by callers; this package import stays light so
``tests/test_imports.py`` can pinpoint a broken submodule):

* :mod:`repro.dist.sharding`    — ``Policy`` + PartitionSpec rule trees for
  the param / optimizer / batch / cache structs in ``repro.launch.shapes``.
* :mod:`repro.dist.hints`       — scoped tracing hints (``sharding_hints``)
  whose ``gather_params`` / ``act_seq`` call sites in ``repro.models.model``
  are *identity no-ops* outside the context.
* :mod:`repro.dist.pipeline`    — GPipe microbatch schedule over
  ``lax.ppermute`` (matches sequential execution, differentiable).
* :mod:`repro.dist.compression` — int8 quantization, error-feedback gradient
  compression, and compressed cross-pod all-reduce.
* :mod:`repro.dist.forest`      — cell-partitioned sharded radix-tree forest
  construction over capacity-bounded per-shard leaf windows (equal,
  occupancy-rebalanced, or explicit cell partitions), owner-routed sampling,
  and windowed delta updates — all bit-identical to the single-device build
  (the module docstring states the partitioning and windowing contracts).
"""
