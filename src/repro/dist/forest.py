"""Cell-partitioned sharded radix-tree forests (multi-device Sec. 3).

The paper's guide cells make every per-cell radix tree independent: a
separator that crosses a cell boundary is clamped to the sentinel distance,
so no tree edge ever crosses a cell. That is exactly a distribution
boundary — this module partitions the ``m`` guide cells *contiguously* over
the mesh data axis, and because shard boundaries are aligned to cell
boundaries, **no cross-device tree edges exist by construction**.

Windowed shard-local builds (the scaling contract; tests pin it):

* Leaves are sorted by value, and cells are contiguous in leaf space, so a
  contiguous cell range owns a **contiguous leaf range**. Each shard's build
  runs over only that range, padded to a static ``capacity`` (shapes must be
  static under ``shard_map``): per-device tree work is the O(C log C)
  nearest-greater descent over its C-sized window, **not** O(n log n) over
  the world — the per-device window provably shrinks with the shard count
  (``tests/test_dist_forest.py`` asserts this on window sizes, not clocks).
* The plan (cell bounds -> leaf windows -> capacity) is derived on host from
  the *device-computed* CDF, so window boundaries agree bit-for-bit with
  what every shard computes under ``shard_map``. A window may include a few
  unowned neighbor leaves (capacity padding / clamping); ownership masking
  in ``core.forest._build_cell_trees`` keeps their slots ``INVALID``.
* The cell partition may be **unequal** (``occupancy_partition``): contiguous
  and cell-aligned, but balanced by *leaf occupancy* so spiky distributions
  no longer pile onto one shard. Equal-width ``cell_partition`` stays the
  default (requires ``D | m``); ``rebalance=True`` opts into occupancy
  balancing; ``partition=`` pins explicit bounds.
* All stored references are *global*: child refs, leaf refs (``~i``), guide
  table entries, and ``cell_first`` use global leaf indices. A node slot is
  owned by the shard owning its leaf's cell; slot ownership is a disjoint
  partition, so scatter-maxing the per-shard windows (unowned slots
  ``INVALID`` = int32 min) reconstructs the exact single-device arrays —
  :func:`gather_forest` is **bit-identical** to ``repro.core.build_forest``.
* The CDF is produced by a **distributed scan** over the fixed
  ``core.cdf.SCAN_CHUNKS`` reassociation grid: each device scans its chunk
  rows locally (optionally through the ``kernels.cdf_scan`` Pallas kernel in
  raw mode), chunk totals are exchanged with an exact ``psum`` scatter-gather
  (disjoint one-hot support, so the reduction adds zeros — no rounding), and
  every device re-derives the serial carry chain identically. The carry is
  deliberately *not* a ``psum`` of totals: a tree reduction has
  order-dependent rounding, and tree topology depends on CDF *bit patterns*.
* Sampling is an **owner-routed bulk drain** (Hübschle-Schneider & Sanders:
  bulk queries are the natural parallel granularity). The batch is sharded
  over the mesh data axis; each shard buckets its ~B/D draws by owning
  shard (cell id against the replicated partition bounds, stable sort,
  host-planned static bucket capacity), exchanges buckets with one
  ``all_to_all``, runs the window-local Algorithm-2 descent on **only the
  ~B/D draws it owns** (the descent ``while_loop`` terminates on the local
  deepest lane, not the global one), and routes interval ids back through a
  second ``all_to_all`` plus the inverse sort permutation — elementwise
  identical to ``core.sample.sample_forest``, with per-shard work that
  *shrinks* as devices grow instead of staying O(B) per shard. The old
  replicated masked-psum merge (every shard descends the full batch, exact
  one-owner-per-lane ``psum``) is kept behind ``routed=False`` as the
  reference oracle; the conformance suite runs both.

Delta updates (:func:`update_forest_sharded`): a weight update patches the
CDF through the same fixed ``SCAN_CHUNKS`` grid (identical reassociation, so
the result is bit-identical to a from-scratch scan), recomputes the
Algorithm-1 per-element work through :mod:`repro.kernels.forest_delta`
(new separator distances + changed-leaf-bits mask), and rebuilds only
window-sized problems — the dirty-gated program runs the tree build on
**only the dirty shards** (clean shards pass their window and cell-table
rows through byte-for-byte, so a sparse update does strictly less device
work than a full rebuild), and a no-op delta returns without touching the
trees at all. The result is bit-identical to a from-scratch
sharded rebuild over the same partition (the delta differential tests gate
this).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.cdf import (
    SCAN_CHUNKS,
    chunk_bounds,
    finalize_cdf,
    lower_bounds,
    scan_chunk_rows,
)
from repro.core.forest import (
    INVALID,
    RadixForest,
    _build_cell_trees,
    _cells,
)
from repro.core.sample import MAX_DEPTH, _bisect
from repro.kernels import ops

# Window capacities are rounded up to this granule: coarse enough that small
# occupancy drift between delta updates reuses the compiled program, fine
# enough that the per-device window still shrinks ~linearly with the shard
# count (a pow2 round would flatten 5/8ths of the sweep).
_CAPACITY_GRANULE = 64
# Routed-drain bucket capacities round up to this granule: small owner-load
# drift between batches reuses the compiled drain program, and the padding
# overhead stays a few lanes per (source, owner) pair.
_BUCKET_GRANULE = 16


class ShardedForest(NamedTuple):
    """Guide table + forest, cell-partitioned over ``n_shards`` devices.

    ``left``/``right`` are (D, C) *windowed* partial node arrays: row ``d``
    holds the contiguous global slot range ``[window_start[d],
    window_start[d] + C)`` with unowned slots ``INVALID``; stored references
    are global. ``table``/``fallback``/``cell_first``/``cdf`` are replicated
    (combined across shards with exact disjoint-support psums at build
    time). ``cell_bounds`` is the contiguous cell partition (shard ``d``
    owns cells ``[cell_bounds[d], cell_bounds[d+1])``); ``window_count`` is
    the number of owned leaves per shard (``window_start`` may be clamped
    below the first owned leaf so the static window fits in ``[0, n)``)."""

    cdf: jax.Array           # (n+1,) f32, replicated
    table: jax.Array         # (m,)  i32, replicated
    left: jax.Array          # (D, C) i32 windowed partial child refs
    right: jax.Array         # (D, C) i32 windowed partial child refs
    cell_first: jax.Array    # (m+1,) i32, replicated
    fallback: jax.Array      # (m,)  bool, replicated
    cell_bounds: jax.Array   # (D+1,) i32 cell partition bounds
    window_start: jax.Array  # (D,)  i32 global leaf offset of each window
    window_count: jax.Array  # (D,)  i32 owned leaves per shard

    @property
    def n(self) -> int:
        return self.cdf.shape[0] - 1

    @property
    def m(self) -> int:
        return self.table.shape[0]

    @property
    def n_shards(self) -> int:
        return self.left.shape[0]

    @property
    def capacity(self) -> int:
        """Static per-shard leaf-window size (the local build problem)."""
        return self.left.shape[1]


def default_mesh(axis: str = "data") -> Mesh:
    """1-D mesh over every local device (8 fake CPU devices in tests)."""
    return Mesh(np.array(jax.devices()), (axis,))


def cell_partition(m: int, n_shards: int) -> np.ndarray:
    """Equal-width shard bounds in cell space: shard d owns [b[d], b[d+1])."""
    if m % n_shards:
        raise ValueError(f"m={m} must divide over {n_shards} shards")
    return np.arange(n_shards + 1, dtype=np.int64) * (m // n_shards)


def occupancy_partition(cell_counts, n_shards: int) -> np.ndarray:
    """Contiguous cell-aligned bounds minimizing the max per-shard leaf load.

    Classic painter's partition: binary-search the smallest capacity for
    which a greedy left-to-right fill needs at most ``n_shards`` segments,
    then emit the greedy cuts at that capacity. Deterministic in the input;
    trailing shards may own empty cell ranges. No absolute per-shard load
    bound is promised — one giant cell forces its whole load onto a single
    shard (cell alignment is the contract) — but the returned partition
    minimizes the max per-shard load over all contiguous cell-aligned
    partitions, which the property tests verify by brute force.
    """
    counts = np.asarray(cell_counts, np.int64)
    if counts.ndim != 1 or counts.size == 0:
        raise ValueError("cell_counts must be a non-empty 1-D array")
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    m = counts.shape[0]
    cum = np.concatenate([[0], np.cumsum(counts)])
    total = int(cum[-1])

    def cuts(cap: int) -> list[int]:
        """Greedy segment ends: each shard takes the longest prefix <= cap."""
        out, b = [], 0
        for _ in range(n_shards):
            if b < m:
                b = int(np.searchsorted(cum, cum[b] + cap, side="right")) - 1
            out.append(b)
        return out

    lo = max(int(counts.max(initial=0)), -(-total // n_shards), 1)
    hi = max(total, lo)
    while lo < hi:
        mid = (lo + hi) // 2
        if cuts(mid)[-1] >= m:
            hi = mid
        else:
            lo = mid + 1
    return np.asarray([0] + cuts(lo), np.int64)


def resolve_partition(
    m: int,
    n_shards: int,
    partition=None,
    rebalance: bool = False,
    cell_counts=None,
) -> np.ndarray:
    """Cell bounds for a build: explicit > occupancy-balanced > equal-width."""
    if partition is not None:
        b = np.asarray(partition, np.int64)
        if (
            b.shape != (n_shards + 1,)
            or b[0] != 0
            or b[-1] != m
            or np.any(np.diff(b) < 0)
        ):
            raise ValueError(
                f"partition must be a nondecreasing (n_shards+1,) bounds "
                f"array from 0 to m={m}, got {b!r}"
            )
        return b
    if rebalance:
        return occupancy_partition(cell_counts, n_shards)
    return cell_partition(m, n_shards)


def pallas_row_scan(rows: jax.Array) -> jax.Array:
    """Local chunk-row scan through the Pallas kernel (raw cumsum mode)."""
    from repro.kernels.cdf_scan import cdf_scan

    return cdf_scan(
        rows, softmax=False, normalize=False,
        interpret=jax.default_backend() != "tpu",
    )


def _distributed_raw_scan(w_rows: jax.Array, axis: str, n: int, row_scan=None):
    """Inside ``shard_map``: (C/D, L) local rows -> (n,) full raw scan.

    Bit-identical to ``core.cdf.chunked_cumsum`` on the concatenated rows:
    same per-row scans, same serial carry chain (re-derived on every device
    from the exact psum-gathered totals), same final adds."""
    Cl, L = w_rows.shape
    idx = jax.lax.axis_index(axis)
    local = jnp.cumsum(w_rows, axis=-1) if row_scan is None else row_scan(w_rows)
    my = idx * Cl + jnp.arange(Cl, dtype=jnp.int32)
    # Exact all-gather of chunk totals: one-hot scatter + psum only ever adds
    # zeros to the single contributor.
    totals = jax.lax.psum(
        jnp.zeros((SCAN_CHUNKS,), local.dtype).at[my].set(local[:, -1]), axis
    )
    carry = jnp.concatenate(
        [jnp.zeros((1,), local.dtype), jnp.cumsum(totals)[:-1]]
    )
    out = local + carry[my, None]
    full = jax.lax.psum(
        jnp.zeros((SCAN_CHUNKS, L), local.dtype).at[my].set(out), axis
    )
    return full.reshape(-1)[:n]


def _shard_count(mesh: Mesh, axis: str) -> int:
    D = int(mesh.shape[axis])
    if SCAN_CHUNKS % D:
        raise ValueError(
            f"shard count {D} must divide SCAN_CHUNKS={SCAN_CHUNKS}"
        )
    if jax.config.jax_enable_x64:
        # build_cdf switches to float64 accumulation under x64; the chunked
        # float32 scan cannot reproduce that bit-for-bit, so fail loudly
        # instead of silently breaking the conformance contract.
        raise NotImplementedError(
            "repro.dist.forest requires the float32 chunked scan; "
            "disable jax_enable_x64"
        )
    return D


@functools.lru_cache(maxsize=128)
def _cdf_builder(mesh: Mesh, axis: str, n: int, row_scan):
    """Cached jitted distributed-CDF program (keyed by mesh/shape)."""

    def shard_fn(w_rows):
        return finalize_cdf(_distributed_raw_scan(w_rows, axis, n, row_scan))

    return jax.jit(shard_map(
        shard_fn, mesh=mesh, in_specs=P(axis), out_specs=P(), check_rep=False
    ))


def build_cdf_sharded(
    weights: jax.Array, mesh: Mesh | None = None, axis: str = "data",
    row_scan=None,
) -> jax.Array:
    """Distributed CDF build: local chunk scans + exact cross-device carry.

    Returns the replicated (n+1,) cdf, bit-identical to
    ``core.cdf.build_cdf(weights, row_scan=row_scan)``."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    _shard_count(mesh, axis)
    w = jnp.asarray(weights, jnp.float32)
    return _cdf_builder(mesh, axis, int(w.shape[0]), row_scan)(scan_chunk_rows(w))


@functools.partial(jax.jit, static_argnames=("m",))
def _device_cells(cdf: jax.Array, m: int) -> jax.Array:
    """Guide cell of every leaf, with the device's own float ops (the plan
    must agree bit-for-bit with what shard_fn computes)."""
    return _cells(lower_bounds(cdf), m)


def _use_pallas() -> bool:
    return ops.use_pallas_default()


def _round_capacity(max_count: int, n: int) -> int:
    c = -(-max(int(max_count), 1) // _CAPACITY_GRANULE) * _CAPACITY_GRANULE
    return min(c, n)


def _plan_windows(cells_np: np.ndarray, bounds: np.ndarray, n: int):
    """Per-shard leaf windows for a cell partition.

    Returns ``(starts, counts, capacity)``: true first-owned-leaf indices,
    owned leaf counts, and the static window capacity. ``cells_np`` is
    nondecreasing (leaves sorted by value), so each shard's owned leaves are
    the contiguous range ``[starts[d], starts[d] + counts[d])``."""
    starts = np.searchsorted(cells_np, bounds[:-1], side="left").astype(np.int64)
    ends = np.searchsorted(cells_np, bounds[1:], side="left").astype(np.int64)
    counts = ends - starts
    return starts, counts, _round_capacity(counts.max(initial=1), n)


def _window_build_local(
    cdf, d_full, bounds, starts, idx, *, m: int, n: int, cap: int,
    m_cap: int, fallback_slack: int,
):
    """One shard's windowed tree build (inside ``shard_map``): slice the
    ``cap``-sized leaf window, build the owned cell range's trees. Shared by
    the full builder and the dirty-gated delta builder — both must run the
    byte-identical program or the delta bit-identity contract breaks."""
    data = lower_bounds(cdf)
    start = starts[idx]
    cell_lo, cell_hi = bounds[idx], bounds[idx + 1]
    wdata = jax.lax.dynamic_slice(data, (start,), (cap,))
    wcells = _cells(wdata, m)
    if cap > 1:
        wd = jax.lax.dynamic_slice(d_full, (start,), (cap - 1,))
    else:
        wd = jnp.zeros((0,), jnp.uint32)
    left, right, tbl, cf, fb = _build_cell_trees(
        wdata, wd, wcells, m=m, cell_lo=cell_lo, m_local=m_cap,
        m_owned=cell_hi - cell_lo, node_offset=start, n_total=n,
        fallback_slack=fallback_slack,
    )
    return tbl, left, right, cf, fb.astype(jnp.int32)


def _combine_cell_rows(tbl, cf, fb_i32, bounds, idx, *, m: int, m_cap: int, axis: str):
    """Combine owned per-cell rows into replicated (m,) tables: targets are
    disjoint across shards and slack rows route to m (dropped), so the psum
    only ever adds zeros to the single contributor."""
    cell_lo, cell_hi = bounds[idx], bounds[idx + 1]
    cids = cell_lo + jnp.arange(m_cap, dtype=jnp.int32)
    owned_c = jnp.arange(m_cap, dtype=jnp.int32) < (cell_hi - cell_lo)
    tgt = jnp.where(owned_c, cids, m)
    table_g = jax.lax.psum(
        jnp.zeros((m,), jnp.int32).at[tgt].set(tbl, mode="drop"), axis
    )
    cf_g = jax.lax.psum(
        jnp.zeros((m,), jnp.int32).at[tgt].set(cf, mode="drop"), axis
    )
    fb_g = jax.lax.psum(
        jnp.zeros((m,), jnp.int32).at[tgt].set(fb_i32, mode="drop"), axis
    )
    return table_g, cf_g, fb_g > 0


@functools.lru_cache(maxsize=128)
def _windowed_builder(
    mesh: Mesh, axis: str, m: int, n: int, cap: int, m_cap: int,
    fallback_slack: int,
):
    """Cached jitted windowed-build program.

    Inputs (all replicated): the cdf, the global separator distances, the
    cell partition bounds, and the clamped window starts. Each device slices
    its own ``cap``-sized leaf window and builds only the trees of its owned
    cell range; per-cell outputs combine into replicated global tables via
    exact disjoint-support psums."""

    def shard_fn(cdf, d_full, bounds, starts):
        idx = jax.lax.axis_index(axis)
        tbl, left, right, cf, fb = _window_build_local(
            cdf, d_full, bounds, starts, idx, m=m, n=n, cap=cap,
            m_cap=m_cap, fallback_slack=fallback_slack,
        )
        table_g, cf_g, fb_g = _combine_cell_rows(
            tbl, cf, fb, bounds, idx, m=m, m_cap=m_cap, axis=axis
        )
        return table_g, left[None], right[None], cf_g, fb_g

    return jax.jit(shard_map(
        shard_fn, mesh=mesh, in_specs=(P(), P(), P(), P()),
        out_specs=(P(), P(axis), P(axis), P(), P()),
        check_rep=False,
    ))


@functools.lru_cache(maxsize=128)
def _windowed_delta_builder(
    mesh: Mesh, axis: str, m: int, n: int, cap: int, m_cap: int,
    fallback_slack: int,
):
    """Cached jitted **dirty-gated** windowed-build program (delta updates).

    Like :func:`_windowed_builder` plus the previous forest's per-shard
    windows, the replicated old cell tables, and a replicated (D,) dirty
    mask. Each shard runs the window build **only when its dirty flag is
    set** (``lax.cond`` executes one branch, so a sparse update really does
    strictly less device tree work than a full rebuild); clean shards
    contribute their old window rows and old cell-table rows byte-for-byte.
    That reuse is exact: a clean shard's owned leaf bits are unchanged and
    the window plan is unchanged, so every one of its outputs — child refs
    *and* its ``table``/``cell_first``/``fallback`` rows, all pure functions
    of the owned window data — would rebuild to the identical bits (the
    delta differential suite gates this)."""

    def shard_fn(cdf, d_full, bounds, starts, dirty,
                 old_left, old_right, old_table, old_cf, old_fb):
        idx = jax.lax.axis_index(axis)
        cell_lo = bounds[idx]

        def build(_):
            return _window_build_local(
                cdf, d_full, bounds, starts, idx, m=m, n=n, cap=cap,
                m_cap=m_cap, fallback_slack=fallback_slack,
            )

        def keep(_):
            safe = jnp.clip(cell_lo + jnp.arange(m_cap, dtype=jnp.int32),
                            0, m - 1)
            return (old_table[safe], old_left[0], old_right[0],
                    old_cf[safe], old_fb[safe].astype(jnp.int32))

        tbl, left, right, cf, fb = jax.lax.cond(
            dirty[idx] > 0, build, keep, operand=None
        )
        table_g, cf_g, fb_g = _combine_cell_rows(
            tbl, cf, fb, bounds, idx, m=m, m_cap=m_cap, axis=axis
        )
        return table_g, left[None], right[None], cf_g, fb_g

    return jax.jit(shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(axis), P(axis), P(), P(), P()),
        out_specs=(P(), P(axis), P(axis), P(), P()),
        check_rep=False,
    ))


def _separator_distances_for(cdf: jax.Array, m: int) -> jax.Array:
    """Global (n-1,) separator distances via the forest_delta kernel path
    (the Algorithm-1 per-element work; bit-identical to
    ``core.forest._separator_distances`` on the same lower bounds)."""
    return ops.forest_delta(lower_bounds(cdf), m, use_pallas=_use_pallas())


def build_forest_from_cdf_sharded(
    cdf: jax.Array,
    m: int,
    mesh: Mesh | None = None,
    axis: str = "data",
    fallback_slack: int = 2,
    partition=None,
    rebalance: bool = False,
    d_full: jax.Array | None = None,
    cells_np: np.ndarray | None = None,
    capacity: int | None = None,
) -> ShardedForest:
    """Windowed shard-local forest build over a replicated CDF.

    Host-side planning (cell occupancy -> partition -> leaf windows ->
    static capacity) runs on the device-computed cell ids, then one
    ``shard_map`` builds every shard's window. Gathering the result
    (:func:`gather_forest`) is bit-identical to
    ``core.build_forest_from_cdf(cdf, m)``. ``d_full``/``cells_np`` let the
    delta-update path feed in the distances and cell ids it already
    computed (they must match the device's own — bit-identity rests on it).
    ``capacity`` pins the static window size instead of the planned one
    (must fit every shard's owned leaf count) — the hysteresis hook:
    :func:`update_forest_sharded` passes the previous forest's capacity so
    occupancy drift below the old window reuses the compiled program.
    """
    mesh = mesh if mesh is not None else default_mesh(axis)
    D = _shard_count(mesh, axis)
    cdf = jnp.asarray(cdf, jnp.float32)
    n = int(cdf.shape[0]) - 1
    if cells_np is None:
        cells_np = np.asarray(_device_cells(cdf, m))
    bounds = resolve_partition(
        m, D, partition=partition, rebalance=rebalance,
        cell_counts=(
            np.bincount(cells_np, minlength=m)
            if partition is None and rebalance else None
        ),
    )
    starts, counts, cap = _plan_windows(cells_np, bounds, n)
    if capacity is not None:
        if capacity < counts.max(initial=1):
            raise ValueError(
                f"capacity={capacity} below the plan's max owned leaf "
                f"count {int(counts.max(initial=1))}"
            )
        cap = min(int(capacity), n)
    w_starts = np.clip(starts, 0, n - cap)
    m_cap = _round_capacity(np.diff(bounds).max(initial=1), m)
    if d_full is None:
        d_full = _separator_distances_for(cdf, m)
    table, left, right, cf, fb = _windowed_builder(
        mesh, axis, m, n, cap, m_cap, fallback_slack
    )(
        cdf,
        d_full,
        jnp.asarray(bounds, jnp.int32),
        jnp.asarray(w_starts, jnp.int32),
    )
    return ShardedForest(
        cdf, table, left, right,
        jnp.concatenate([cf, jnp.asarray([n - 1], jnp.int32)]),
        fb,
        jnp.asarray(bounds, jnp.int32),
        jnp.asarray(w_starts, jnp.int32),
        jnp.asarray(counts, jnp.int32),
    )


def build_forest_sharded(
    weights: jax.Array,
    m: int,
    mesh: Mesh | None = None,
    axis: str = "data",
    fallback_slack: int = 2,
    row_scan=None,
    partition=None,
    rebalance: bool = False,
    capacity: int | None = None,
) -> ShardedForest:
    """Distributed scan -> windowed per-shard cell-range tree build.

    Each device derives the full CDF from the distributed chunked scan, then
    builds only the trees of its own cell range over a capacity-bounded
    local leaf window, with node ids in the global index space. Gathering
    the partials (:func:`gather_forest`) is bit-identical to
    ``core.build_forest``."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    _shard_count(mesh, axis)
    w = jnp.asarray(weights, jnp.float32)
    cdf = _cdf_builder(mesh, axis, int(w.shape[0]), row_scan)(scan_chunk_rows(w))
    return build_forest_from_cdf_sharded(
        cdf, m, mesh=mesh, axis=axis, fallback_slack=fallback_slack,
        partition=partition, rebalance=rebalance, capacity=capacity,
    )


def build_forest_sharded_auto(
    weights: jax.Array,
    m: int,
    mesh: Mesh | None = None,
    axis: str = "data",
    fallback_slack: int = 2,
    rebalance: bool = False,
) -> tuple[ShardedForest, Mesh]:
    """Caller-friendly build: default mesh over all devices and ``m`` rounded
    up to the next shard multiple (the equal cell-aligned partition needs
    D | m; occupancy rebalancing has no such constraint but keeps the same
    guide resolution). The shared glue for opt-in call sites
    (``serve.sampler.ForestSampler``, ``data.mixture.MixtureSampler``);
    returns the forest and the mesh to sample with."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    D = int(mesh.shape[axis])
    m = -(-m // D) * D
    return (
        build_forest_sharded(
            weights, m, mesh=mesh, axis=axis, fallback_slack=fallback_slack,
            rebalance=rebalance,
        ),
        mesh,
    )


def update_forest_sharded(
    forest: ShardedForest,
    weights: jax.Array | None = None,
    *,
    weights_delta=None,
    base_weights=None,
    mesh: Mesh | None = None,
    axis: str = "data",
    fallback_slack: int = 2,
    row_scan=None,
    with_stats: bool = False,
):
    """Delta update: rebuild only the shards whose owned windows changed.

    ``weights`` is the full new weight vector (or pass ``weights_delta`` +
    ``base_weights`` and the float32 sum is formed here). The CDF is patched
    through the fixed ``SCAN_CHUNKS`` reassociation grid (same row scans,
    same serial carry — bit-identical to a from-scratch distributed scan);
    the Algorithm-1 per-element re-work (new separator distances + the
    changed-leaf-bits mask) comes from :mod:`repro.kernels.forest_delta`.
    Shards whose leaf windows carry no changed bits keep their partial
    arrays byte-for-byte; a no-op delta skips the tree rebuild entirely.

    **Capacity hysteresis**: the fresh plan's capacity is only adopted when
    it *grows* past the current window — while the new plan still fits,
    the old (possibly larger) capacity is kept, so an adversarial weight
    stream oscillating across a 64-leaf granule boundary stops recompiling
    the windowed build/sampling programs on every update (the regression
    test drives exactly that stream). The result is **bit-identical** to
    ``build_forest_sharded(weights, m, partition=forest.cell_bounds,
    capacity=<the kept capacity>)``, and its gather stays bit-identical to
    the single-device build (window capacity never affects stored bits).

    With ``with_stats=True`` also returns a dict: ``dirty_shards`` /
    ``dirty_chunks`` (scan-grid rows re-spanned by changed CDF entries) /
    ``plan_changed`` (leaf windows moved -> full windowed rebuild) /
    ``rebuilt`` (the tree-build shard_map actually ran) /
    ``rebuilt_windows`` (window builds the devices actually executed: the
    dirty-gated program runs the tree build only on dirty shards, so a
    sparse update does strictly less device work than a full rebuild —
    the structural fact the delta benchmarks pin, never wall-clock) /
    ``capacity`` (the static window adopted) / ``capacity_kept``
    (hysteresis retained a window larger than the fresh plan's).
    """
    mesh = mesh if mesh is not None else default_mesh(axis)
    D = _shard_count(mesh, axis)
    if forest.n_shards != D:
        raise ValueError(
            f"forest has {forest.n_shards} shards but mesh axis has {D}"
        )
    if weights is None:
        if weights_delta is None or base_weights is None:
            raise ValueError(
                "pass weights, or both weights_delta and base_weights"
            )
        weights = (
            jnp.asarray(base_weights, jnp.float32)
            + jnp.asarray(weights_delta, jnp.float32)
        )
    w = jnp.asarray(weights, jnp.float32)
    n, m = forest.n, forest.m
    if int(w.shape[0]) != n:
        raise ValueError(
            f"delta update keeps n fixed: forest has {n} intervals, "
            f"got {int(w.shape[0])} weights"
        )
    new_cdf = _cdf_builder(mesh, axis, n, row_scan)(scan_chunk_rows(w))
    old_bits = np.asarray(forest.cdf).view(np.uint32)
    new_bits = np.asarray(new_cdf).view(np.uint32)
    cb = chunk_bounds(n)
    changed_cdf = np.flatnonzero(new_bits[1:] != old_bits[1:])
    dirty_chunks = int(
        np.unique(np.searchsorted(cb, changed_cdf, side="right") - 1).size
    )

    if changed_cdf.size == 0:
        stats = dict(
            dirty_shards=0, dirty_chunks=0, plan_changed=False, rebuilt=False,
            rebuilt_windows=0, capacity=forest.capacity, capacity_kept=False,
        )
        out = forest._replace(cdf=new_cdf)  # same bits; fresh buffer
        return (out, stats) if with_stats else out

    bounds = np.asarray(forest.cell_bounds, np.int64)
    # Algorithm-1 re-work for the changed weights, via the Pallas kernel
    # entry point: new distances feed the rebuild, the changed-bits mask
    # drives per-shard dirtiness.
    d_new, leaf_changed = ops.forest_delta_update(
        lower_bounds(forest.cdf), lower_bounds(new_cdf), m,
        use_pallas=_use_pallas(),
    )
    cells_np = np.asarray(_device_cells(new_cdf, m))
    starts, counts, fresh_cap = _plan_windows(cells_np, bounds, n)
    # Hysteresis: keep the compiled program's window while the new plan
    # still fits; only a genuine overflow re-plans (and recompiles).
    cap = forest.capacity if fresh_cap <= forest.capacity else fresh_cap
    w_starts = np.clip(starts, 0, n - cap)
    plan_same = (
        cap == forest.capacity
        and np.array_equal(w_starts, np.asarray(forest.window_start))
        and np.array_equal(counts, np.asarray(forest.window_count))
    )
    lc = np.asarray(leaf_changed)
    dirty = np.array(
        [bool(lc[s : s + c].any()) for s, c in zip(starts, counts)]
    )
    if plan_same:
        # Dirty-gated rebuild: only the dirty shards run their window build
        # on device (lax.cond executes one branch); clean shards pass their
        # old window rows and old cell-table rows through byte-for-byte.
        m_cap = _round_capacity(np.diff(bounds).max(initial=1), m)
        table, left, right, cf, fb = _windowed_delta_builder(
            mesh, axis, m, n, cap, m_cap, fallback_slack
        )(
            new_cdf, d_new,
            jnp.asarray(bounds, jnp.int32),
            jnp.asarray(w_starts, jnp.int32),
            jnp.asarray(dirty, jnp.int32),
            forest.left, forest.right, forest.table,
            forest.cell_first[:m], forest.fallback,
        )
        out = ShardedForest(
            new_cdf, table, left, right,
            jnp.concatenate([cf, jnp.asarray([n - 1], jnp.int32)]),
            fb, forest.cell_bounds, forest.window_start, forest.window_count,
        )
    else:
        out = build_forest_from_cdf_sharded(
            new_cdf, m, mesh=mesh, axis=axis, fallback_slack=fallback_slack,
            partition=bounds, d_full=d_new, cells_np=cells_np, capacity=cap,
        )
    stats = dict(
        dirty_shards=int(dirty.sum()) if plan_same else D,
        dirty_chunks=dirty_chunks,
        plan_changed=not plan_same,
        rebuilt=True,
        rebuilt_windows=int(dirty.sum()) if plan_same else D,
        capacity=cap,
        capacity_kept=cap > fresh_cap,
    )
    return (out, stats) if with_stats else out


def _round_bucket(count: int, limit: int) -> int:
    """Static per-(source, owner) bucket capacity: the observed max count
    rounded up to the bucket granule (program reuse under owner-load drift),
    never above the per-shard lane count (can't send more than you hold)."""
    k = -(-max(int(count), 1) // _BUCKET_GRANULE) * _BUCKET_GRANULE
    return max(min(k, limit), 1)


@functools.partial(jax.jit, static_argnames=("m",))
def _draw_owners(xi: jax.Array, bounds: jax.Array, m: int) -> jax.Array:
    """Owning shard of each uniform: cell id against the partition bounds.

    The same float/int ops the drain program runs under ``shard_map`` — the
    host-side bucket plan and the device-side routing must agree draw for
    draw. Empty shards (repeated bounds) are skipped by the right-sided
    search, so every draw has exactly one owner."""
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    return jnp.clip(
        jnp.searchsorted(bounds, g, side="right").astype(jnp.int32) - 1,
        0, bounds.shape[0] - 2,
    )


def _drain_plan(forest: ShardedForest, xi: jax.Array, D: int):
    """Host-side routed-drain plan: pad the batch to D lanes-per-shard, count
    draws per (source shard, owning shard), round the max to the static
    bucket capacity. Returns ``(plan, xi_padded)``."""
    B = int(xi.shape[0])
    if B == 0:
        raise ValueError("cannot drain an empty batch")
    lanes = -(-B // D)
    b_pad = lanes * D
    xi_p = jnp.pad(
        jnp.asarray(xi, jnp.float32), (0, b_pad - B), constant_values=-1.0
    )
    owners = np.asarray(_draw_owners(xi_p, forest.cell_bounds, forest.m))
    counts = np.stack(
        [np.bincount(row, minlength=D) for row in owners.reshape(D, lanes)]
    )
    K = _round_bucket(counts.max(initial=1), lanes)
    plan = dict(
        batch=B, padded_batch=b_pad, lanes_per_shard=lanes,
        bucket_capacity=K, descent_lanes=D * K, send_counts=counts,
    )
    return plan, xi_p


def drain_plan(
    forest: ShardedForest, xi: jax.Array, mesh: Mesh | None = None,
    axis: str = "data",
) -> dict:
    """The routed drain's bucket plan for a batch (what the devices will do,
    structurally): ``lanes_per_shard`` (the batch shard each device holds),
    ``bucket_capacity`` (static per-(source, owner) bucket), and
    ``descent_lanes`` (lanes each shard's Algorithm-2 descent runs over —
    ~B/D for balanced owner loads, vs the full B every shard pays on the
    masked-psum oracle path). Tests assert scaling on these shapes, never
    on wall-clock."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    plan, _ = _drain_plan(forest, xi, int(mesh.shape[axis]))
    return plan


def sample_sharded(
    forest: ShardedForest,
    xi: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "data",
    use_fallback: bool = True,
    routed: bool = True,
    on_mismatch: str = "raise",
    with_stats: bool = False,
) -> jax.Array:
    """Algorithm 2 over the sharded forest: owner-routed bulk drain.

    ``routed=True`` (default): the batch is sharded over the mesh data axis,
    each shard stably sorts its ~B/D draws by owning shard into
    capacity-padded buckets (host-planned static shapes), one ``all_to_all``
    exchanges the buckets, the owner resolves **only its owned draws** over
    its local window (every edge of an owned cell's tree stays inside the
    window, and global node id minus window start is the local slot; the
    descent loop terminates on the *local* deepest lane), and a second
    ``all_to_all`` plus the inverse sort permutation routes interval ids
    back to the requesting lanes.

    ``routed=False`` keeps the replicated masked-psum merge as a reference
    oracle: every shard descends the full batch and the per-lane results
    combine with an exact one-owner-per-lane ``psum``.

    Both paths are elementwise identical to ``core.sample.sample_forest`` on
    the gathered forest. Returns global interval ids.

    ``on_mismatch`` picks the behavior when the forest's shard count does
    not match the mesh data axis (a restore onto a shrunk/grown mesh):
    ``"raise"`` (default, the strict contract) or ``"degrade"`` — gather
    the forest (:func:`gather_forest` is exact) and resolve the whole
    batch with the single-device descent, elementwise-identical to the
    sharded drain, flagged ``degraded=True`` in the stats dict that
    ``with_stats=True`` adds to the return."""
    if on_mismatch not in ("raise", "degrade"):
        raise ValueError(
            f"on_mismatch must be 'raise' or 'degrade', got {on_mismatch!r}"
        )
    mesh = mesh if mesh is not None else default_mesh(axis)
    D = int(mesh.shape[axis])
    stats = dict(degraded=False, n_shards=forest.n_shards, mesh_devices=D)
    if forest.n_shards != D:
        if on_mismatch == "raise":
            raise ValueError(
                f"forest has {forest.n_shards} shards but mesh axis has {D}"
            )
        from repro.core.sample import sample_forest

        out = sample_forest(
            gather_forest(forest), jnp.asarray(xi, jnp.float32),
            use_fallback=use_fallback,
        )
        stats["degraded"] = True
        return (out, stats) if with_stats else out
    if not routed:
        out = _sampler(
            mesh, axis, forest.m, forest.n, forest.capacity, use_fallback
        )(
            forest.table, forest.left, forest.right, forest.fallback,
            forest.cdf, forest.cell_first, forest.cell_bounds,
            forest.window_start, jnp.asarray(xi, jnp.float32),
        )
        return (out, stats) if with_stats else out
    plan, xi_p = _drain_plan(forest, xi, D)
    out = _routed_sampler(
        mesh, axis, forest.m, forest.n, forest.capacity, use_fallback,
        plan["lanes_per_shard"], plan["bucket_capacity"],
    )(
        forest.table, forest.left, forest.right, forest.fallback,
        forest.cdf, forest.cell_first, forest.cell_bounds,
        forest.window_start, xi_p,
    )
    out = out[: plan["batch"]]
    return (out, stats) if with_stats else out


@functools.lru_cache(maxsize=128)
def _routed_sampler(
    mesh: Mesh, axis: str, m: int, n: int, cap: int, use_fallback: bool,
    lanes: int, K: int,
):
    """Cached jitted owner-routed all-to-all drain program.

    Each shard holds ``lanes`` draws of the batch and a ``(D, K)`` bucket
    grid; the tiled ``all_to_all`` is a transpose of that grid across the
    mesh (and hence its own inverse — the identical collective routes the
    answers back). Bucket padding lanes carry the sentinel ``-1.0`` and are
    resolved to ``done`` before the descent starts, so they cost nothing."""
    D = int(mesh.shape[axis])

    def shard_fn(table, left_l, right_l, fb, cdf, cell_first, bounds, starts, xi_l):
        idx = jax.lax.axis_index(axis)
        left_l, right_l = left_l[0], right_l[0]
        start = starts[idx]

        # Bucket my batch shard by owning shard: stable sort keeps duplicate
        # uniforms and equal-owner draws in batch order, and the (owner,
        # within-bucket rank) pair is exactly the slot the owner will answer
        # at — the round trip needs no index payload at all.
        g = jnp.clip(jnp.floor(xi_l * jnp.float32(m)).astype(jnp.int32),
                     0, m - 1)
        owner = jnp.clip(
            jnp.searchsorted(bounds, g, side="right").astype(jnp.int32) - 1,
            0, D - 1,
        )
        order = jnp.argsort(owner)                       # stable
        so, sx = owner[order], xi_l[order]
        seg = jnp.searchsorted(so, jnp.arange(D, dtype=jnp.int32))
        rank = jnp.arange(lanes, dtype=jnp.int32) - seg[so].astype(jnp.int32)
        send = jnp.full((D, K), -1.0, jnp.float32).at[so, rank].set(
            sx, mode="drop"
        )
        recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=True)

        # Window-local Algorithm-2 descent over only my owned draws (~B/D
        # lanes): the while_loop ends on MY deepest lane, not the world's.
        rx = recv.reshape(-1)
        live = rx >= 0.0
        rg = jnp.clip(jnp.floor(rx * jnp.float32(m)).astype(jnp.int32),
                      0, m - 1)
        j = jnp.where(live, table[rg], jnp.int32(-1))
        if use_fallback:
            flagged = live & fb[rg] & (j >= 0)
            bal = _bisect(cdf, rx, cell_first[rg], cell_first[rg + 1], 32)
            j = jnp.where(flagged, ~bal, j)

        def cond(state):
            j, it = state
            return jnp.any(j >= 0) & (it < MAX_DEPTH)

        def body(state):
            j, it = state
            jw = jnp.clip(j - start, 0, cap - 1)     # window slot of node j
            go_left = rx < cdf[jnp.clip(j, 0, n - 1)]
            nxt = jnp.where(go_left, left_l[jw], right_l[jw])
            return jnp.where(j >= 0, nxt, j), it + 1

        j, _ = jax.lax.while_loop(cond, body, (j, jnp.int32(0)))

        # Route interval ids back: the same all_to_all inverts the exchange,
        # then the inverse sort permutation restores batch order.
        back = jax.lax.all_to_all((~j).reshape(D, K), axis, 0, 0, tiled=True)
        return jnp.zeros((lanes,), jnp.int32).at[order].set(back[so, rank])

    return jax.jit(shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(), P(), P(), P(), P(), P(axis)),
        out_specs=P(axis), check_rep=False,
    ))


@functools.lru_cache(maxsize=128)
def _sampler(mesh: Mesh, axis: str, m: int, n: int, cap: int, use_fallback: bool):
    """Cached jitted replicated masked-psum sampling program (the reference
    oracle the routed drain is verified against: every shard descends the
    full batch; exact merge because every lane has exactly one owner)."""

    def shard_fn(table, left_l, right_l, fb, cdf, cell_first, bounds, starts, xi):
        idx = jax.lax.axis_index(axis)
        left_l, right_l = left_l[0], right_l[0]
        start = starts[idx]
        g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
        owned = (g >= bounds[idx]) & (g < bounds[idx + 1])
        j = jnp.where(owned, table[g], jnp.int32(-1))

        if use_fallback:
            flagged = owned & fb[g] & (j >= 0)
            bal = _bisect(cdf, xi, cell_first[g], cell_first[g + 1], 32)
            j = jnp.where(flagged, ~bal, j)

        def cond(state):
            j, it = state
            return jnp.any(j >= 0) & (it < MAX_DEPTH)

        def body(state):
            j, it = state
            jw = jnp.clip(j - start, 0, cap - 1)     # window slot of node j
            go_left = xi < cdf[jnp.clip(j, 0, n - 1)]
            nxt = jnp.where(go_left, left_l[jw], right_l[jw])
            return jnp.where(j >= 0, nxt, j), it + 1

        j, _ = jax.lax.while_loop(cond, body, (j, jnp.int32(0)))
        return jax.lax.psum(jnp.where(owned, ~j, 0), axis)

    return jax.jit(shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(), P(), P(), P(), P(), P()),
        out_specs=P(), check_rep=False,
    ))


def gather_forest(forest: ShardedForest) -> RadixForest:
    """Combine the per-shard windows into a single-device ``RadixForest``.

    Slot ownership is disjoint and ``INVALID`` is the int32 minimum, so
    scatter-maxing every shard's window at its global offset is the exact
    union of the writes (window padding/overlap only ever contributes
    ``INVALID``)."""
    D, cap = forest.left.shape
    n = forest.n
    idx = (
        forest.window_start[:, None].astype(jnp.int32)
        + jnp.arange(cap, dtype=jnp.int32)[None, :]
    ).reshape(-1)
    left = jnp.full((n,), INVALID, jnp.int32).at[idx].max(
        forest.left.reshape(-1), mode="drop"
    )
    right = jnp.full((n,), INVALID, jnp.int32).at[idx].max(
        forest.right.reshape(-1), mode="drop"
    )
    return RadixForest(
        forest.cdf,
        forest.table,
        left,
        right,
        forest.cell_first,
        forest.fallback,
    )
