"""Cell-partitioned sharded radix-tree forests (multi-device Sec. 3).

The paper's guide cells make every per-cell radix tree independent: a
separator that crosses a cell boundary is clamped to the sentinel distance,
so no tree edge ever crosses a cell. That is exactly a distribution
boundary — this module partitions the ``m`` guide cells *contiguously* over
the mesh data axis, and because shard boundaries are aligned to cell
boundaries, **no cross-device tree edges exist by construction**.

Partitioning contract (load-bearing; tests pin it):

* ``m`` must be divisible by the shard count ``D``. Shard ``d`` owns the
  cell range ``[d*m/D, (d+1)*m/D)`` — i.e. the value range
  ``[d/D, (d+1)/D)`` of the unit interval.
* A node slot (= leaf index) is owned by the shard owning its leaf's cell.
  Ownership of slots is a disjoint partition, so per-shard partial
  ``left``/``right`` arrays (unowned slots ``INVALID`` = int32 min) combine
  exactly by elementwise max — :func:`gather_forest`.
* All stored references are *global*: child refs, leaf refs (``~i``), guide
  table entries, and ``cell_first`` use global leaf indices, so gathered or
  routed results need no re-indexing.
* The CDF is produced by a **distributed scan** over the fixed
  ``core.cdf.SCAN_CHUNKS`` reassociation grid: each device scans its chunk
  rows locally (optionally through the ``kernels.cdf_scan`` Pallas kernel in
  raw mode), chunk totals are exchanged with an exact ``psum`` scatter-gather
  (disjoint one-hot support, so the reduction adds zeros — no rounding), and
  every device re-derives the serial carry chain identically. The carry is
  deliberately *not* a ``psum`` of totals: a tree reduction has
  order-dependent rounding, and tree topology depends on CDF *bit patterns*.
  Result: :func:`build_forest_sharded` is **bit-identical** to the
  single-device :func:`repro.core.build_forest` for every shard count
  dividing ``SCAN_CHUNKS`` (the differential conformance suite in
  ``tests/test_dist_forest.py`` gates this).
* Sampling routes each uniform to its owning shard arithmetically
  (``cell id // (m/D)`` — no search), the owner runs the local Algorithm-2
  descent touching only slots it owns, and results are combined with a
  masked ``psum`` (each lane has exactly one owner, so the sum is exact).

Known tradeoff, by design (see ROADMAP open items): the nearest-greater
sweep over separator distances is executed per device over the full index
window with writes masked to the owned cell range. That keeps every shape
static under ``shard_map`` (leaf counts per cell range are data-dependent);
compacting each shard to a capacity-bounded local window (via the
``node_offset`` parameter of ``core.forest._build_cell_trees``) is the
follow-on, as is rebalancing shards under uneven cell occupancy.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.cdf import SCAN_CHUNKS, finalize_cdf, lower_bounds, scan_chunk_rows
from repro.core.forest import (
    RadixForest,
    _build_cell_trees,
    _cells,
    _separator_distances,
)
from repro.core.sample import MAX_DEPTH, _bisect


class ShardedForest(NamedTuple):
    """Guide table + forest, cell-partitioned over ``n_shards`` devices.

    ``table``/``fallback`` are (m,) arrays laid out as the concatenation of
    the per-shard cell slices (shardable along the data axis); ``left`` /
    ``right`` are (D, n) with row ``d`` holding shard ``d``'s partial node
    arrays (unowned slots ``INVALID``); ``cdf``/``cell_first`` are replicated
    (the cutpoint side tables are needed at shard boundaries)."""

    cdf: jax.Array         # (n+1,) f32, replicated
    table: jax.Array       # (m,)  i32, cell-sharded
    left: jax.Array        # (D, n) i32 partial child refs
    right: jax.Array       # (D, n) i32 partial child refs
    cell_first: jax.Array  # (m+1,) i32, replicated
    fallback: jax.Array    # (m,)  bool, cell-sharded

    @property
    def n(self) -> int:
        return self.left.shape[1]

    @property
    def m(self) -> int:
        return self.table.shape[0]

    @property
    def n_shards(self) -> int:
        return self.left.shape[0]


def default_mesh(axis: str = "data") -> Mesh:
    """1-D mesh over every local device (8 fake CPU devices in tests)."""
    return Mesh(np.array(jax.devices()), (axis,))


def cell_partition(m: int, n_shards: int) -> np.ndarray:
    """Shard boundaries in cell space: shard d owns [b[d], b[d+1])."""
    if m % n_shards:
        raise ValueError(f"m={m} must divide over {n_shards} shards")
    return np.arange(n_shards + 1, dtype=np.int64) * (m // n_shards)


def pallas_row_scan(rows: jax.Array) -> jax.Array:
    """Local chunk-row scan through the Pallas kernel (raw cumsum mode)."""
    from repro.kernels.cdf_scan import cdf_scan

    return cdf_scan(
        rows, softmax=False, normalize=False,
        interpret=jax.default_backend() != "tpu",
    )


def _distributed_raw_scan(w_rows: jax.Array, axis: str, n: int, row_scan=None):
    """Inside ``shard_map``: (C/D, L) local rows -> (n,) full raw scan.

    Bit-identical to ``core.cdf.chunked_cumsum`` on the concatenated rows:
    same per-row scans, same serial carry chain (re-derived on every device
    from the exact psum-gathered totals), same final adds."""
    Cl, L = w_rows.shape
    idx = jax.lax.axis_index(axis)
    local = jnp.cumsum(w_rows, axis=-1) if row_scan is None else row_scan(w_rows)
    my = idx * Cl + jnp.arange(Cl, dtype=jnp.int32)
    # Exact all-gather of chunk totals: one-hot scatter + psum only ever adds
    # zeros to the single contributor.
    totals = jax.lax.psum(
        jnp.zeros((SCAN_CHUNKS,), local.dtype).at[my].set(local[:, -1]), axis
    )
    carry = jnp.concatenate(
        [jnp.zeros((1,), local.dtype), jnp.cumsum(totals)[:-1]]
    )
    out = local + carry[my, None]
    full = jax.lax.psum(
        jnp.zeros((SCAN_CHUNKS, L), local.dtype).at[my].set(out), axis
    )
    return full.reshape(-1)[:n]


def _shard_count(mesh: Mesh, axis: str) -> int:
    D = int(mesh.shape[axis])
    if SCAN_CHUNKS % D:
        raise ValueError(
            f"shard count {D} must divide SCAN_CHUNKS={SCAN_CHUNKS}"
        )
    if jax.config.jax_enable_x64:
        # build_cdf switches to float64 accumulation under x64; the chunked
        # float32 scan cannot reproduce that bit-for-bit, so fail loudly
        # instead of silently breaking the conformance contract.
        raise NotImplementedError(
            "repro.dist.forest requires the float32 chunked scan; "
            "disable jax_enable_x64"
        )
    return D


@functools.lru_cache(maxsize=128)
def _cdf_builder(mesh: Mesh, axis: str, n: int, row_scan):
    """Cached jitted distributed-CDF program (keyed by mesh/shape)."""

    def shard_fn(w_rows):
        return finalize_cdf(_distributed_raw_scan(w_rows, axis, n, row_scan))

    return jax.jit(shard_map(
        shard_fn, mesh=mesh, in_specs=P(axis), out_specs=P(), check_rep=False
    ))


def build_cdf_sharded(
    weights: jax.Array, mesh: Mesh | None = None, axis: str = "data",
    row_scan=None,
) -> jax.Array:
    """Distributed CDF build: local chunk scans + exact cross-device carry.

    Returns the replicated (n+1,) cdf, bit-identical to
    ``core.cdf.build_cdf(weights, row_scan=row_scan)``."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    _shard_count(mesh, axis)
    w = jnp.asarray(weights, jnp.float32)
    return _cdf_builder(mesh, axis, int(w.shape[0]), row_scan)(scan_chunk_rows(w))


def build_forest_sharded(
    weights: jax.Array,
    m: int,
    mesh: Mesh | None = None,
    axis: str = "data",
    fallback_slack: int = 2,
    row_scan=None,
) -> ShardedForest:
    """Distributed scan -> per-shard cell-range tree build, one shard_map.

    Each device derives the full CDF from the distributed scan, then builds
    only the trees of its own cell range (writes masked by ownership), with
    node ids in the global index space. Gathering the partials
    (:func:`gather_forest`) is bit-identical to ``core.build_forest``."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    D = _shard_count(mesh, axis)
    if m % D:
        raise ValueError(f"m={m} must divide over the {D}-way cell partition")
    w = jnp.asarray(weights, jnp.float32)
    n = int(w.shape[0])
    cdf, table, left, right, cf, fb = _forest_builder(
        mesh, axis, m, n, fallback_slack, row_scan
    )(scan_chunk_rows(w))
    cell_first = jnp.concatenate([cf, jnp.asarray([n - 1], jnp.int32)])
    return ShardedForest(cdf, table, left, right, cell_first, fb)


@functools.lru_cache(maxsize=128)
def _forest_builder(
    mesh: Mesh, axis: str, m: int, n: int, fallback_slack: int, row_scan
):
    """Cached jitted sharded-build program (keyed by mesh/shape params)."""
    m_local = m // int(mesh.shape[axis])

    def shard_fn(w_rows):
        raw = _distributed_raw_scan(w_rows, axis, n, row_scan)
        cdf = finalize_cdf(raw)
        data = lower_bounds(cdf)
        cells = _cells(data, m)
        d = _separator_distances(data, cells)
        cell_lo = jax.lax.axis_index(axis) * m_local
        left, right, table, cf, fb = _build_cell_trees(
            data, d, cells, m=m, cell_lo=cell_lo, m_local=m_local,
            fallback_slack=fallback_slack,
        )
        return cdf, table, left[None], right[None], cf, fb

    return jax.jit(shard_map(
        shard_fn, mesh=mesh, in_specs=P(axis),
        out_specs=(P(), P(axis), P(axis), P(axis), P(axis), P(axis)),
        check_rep=False,
    ))


def build_forest_sharded_auto(
    weights: jax.Array,
    m: int,
    mesh: Mesh | None = None,
    axis: str = "data",
    fallback_slack: int = 2,
) -> tuple[ShardedForest, Mesh]:
    """Caller-friendly build: default mesh over all devices and ``m`` rounded
    up to the next shard multiple (the cell-aligned partition needs D | m).
    The shared glue for opt-in call sites (``serve.sampler.ForestSampler``,
    ``data.mixture.MixtureSampler``); returns the forest and the mesh to
    sample with."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    D = int(mesh.shape[axis])
    m = -(-m // D) * D
    return (
        build_forest_sharded(
            weights, m, mesh=mesh, axis=axis, fallback_slack=fallback_slack
        ),
        mesh,
    )


def sample_sharded(
    forest: ShardedForest,
    xi: jax.Array,
    mesh: Mesh | None = None,
    axis: str = "data",
    use_fallback: bool = True,
) -> jax.Array:
    """Algorithm 2 over the sharded forest: owner-routed local descent.

    Each uniform's owning shard is pure arithmetic (``cell // (m/D)``); the
    owner resolves it against its local partial node arrays (every edge of an
    owned cell's tree stays inside the shard) and the per-lane results merge
    with a masked ``psum`` — exact, because every lane has exactly one owner.
    Elementwise identical to ``core.sample.sample_forest`` on the gathered
    forest. Returns global interval ids, replicated."""
    mesh = mesh if mesh is not None else default_mesh(axis)
    D = int(mesh.shape[axis])
    if forest.n_shards != D:
        raise ValueError(
            f"forest has {forest.n_shards} shards but mesh axis has {D}"
        )
    return _sampler(mesh, axis, forest.m, forest.n, use_fallback)(
        forest.table, forest.left, forest.right, forest.fallback,
        forest.cdf, forest.cell_first, jnp.asarray(xi, jnp.float32),
    )


@functools.lru_cache(maxsize=128)
def _sampler(mesh: Mesh, axis: str, m: int, n: int, use_fallback: bool):
    """Cached jitted owner-routed sampling program."""
    m_local = m // int(mesh.shape[axis])

    def shard_fn(table_l, left_l, right_l, fb_l, cdf, cell_first, xi):
        idx = jax.lax.axis_index(axis)
        left_l, right_l = left_l[0], right_l[0]
        g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
        cell_lo = idx * m_local
        owned = (g >= cell_lo) & (g < cell_lo + m_local)
        gl = jnp.clip(g - cell_lo, 0, m_local - 1)
        j = jnp.where(owned, table_l[gl], jnp.int32(-1))

        if use_fallback:
            fb = owned & fb_l[gl] & (j >= 0)
            bal = _bisect(cdf, xi, cell_first[g], cell_first[g + 1], 32)
            j = jnp.where(fb, ~bal, j)

        def cond(state):
            j, it = state
            return jnp.any(j >= 0) & (it < MAX_DEPTH)

        def body(state):
            j, it = state
            jj = jnp.clip(j, 0, n - 1)
            go_left = xi < cdf[jj]
            nxt = jnp.where(go_left, left_l[jj], right_l[jj])
            return jnp.where(j >= 0, nxt, j), it + 1

        j, _ = jax.lax.while_loop(cond, body, (j, jnp.int32(0)))
        return jax.lax.psum(jnp.where(owned, ~j, 0), axis)

    return jax.jit(shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(), P(), P()),
        out_specs=P(), check_rep=False,
    ))


def gather_forest(forest: ShardedForest) -> RadixForest:
    """Combine the per-shard partials into a single-device ``RadixForest``.

    Slot ownership is disjoint and ``INVALID`` is the int32 minimum, so an
    elementwise max over the shard axis is the exact union of the writes."""
    return RadixForest(
        forest.cdf,
        forest.table,
        jnp.max(forest.left, axis=0),
        jnp.max(forest.right, axis=0),
        forest.cell_first,
        forest.fallback,
    )
