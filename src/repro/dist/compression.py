"""Gradient compression: int8 quantization with error feedback, and a
compressed cross-pod all-reduce.

Guarantees (asserted by ``tests/test_data_and_serve.py``):

* :func:`quantize_int8` round-to-nearest against a symmetric absmax scale:
  elementwise error <= scale / 2 (the quantization floor). All-zero inputs
  round-trip exactly.
* :func:`compress_grads_with_feedback` carries the quantization residual
  into the next step (error feedback / EF-SGD), so the *accumulated* applied
  gradient tracks the true sum to one-step error instead of accumulating
  bias — naive repeated quantization drifts linearly.
* :func:`make_pod_allreduce` reduces with a **shared, pre-agreed scale**:
  the per-shard absmax is ``pmax``-ed across the pod axis *before*
  quantizing, so every pod quantizes against the same grid and the summed
  int8 payloads dequantize consistently (a per-shard-scale variant showed
  26% error; shared-scale sits at the quantization floor). Payload per hop:
  1 byte/grad + one f32 scale, vs 4 bytes/grad uncompressed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array, scale: jax.Array | None = None):
    """Symmetric absmax int8 quantization. Returns ``(q int8, scale f32)``."""
    x = jnp.asarray(x)
    if scale is None:
        scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    safe = jnp.where(scale > 0, scale, 1.0)
    return q.astype(jnp.float32) * jnp.where(scale > 0, safe, 0.0)


def compress_grads_with_feedback(grads: jax.Array, residual: jax.Array | None):
    """One error-feedback compression step.

    ``residual`` is the carried quantization error from the previous step
    (``None`` on the first call). Returns ``(dequantized, new_residual)``;
    apply ``dequantized`` and thread ``new_residual`` into the next call.
    """
    acc = grads if residual is None else grads + residual
    q, s = quantize_int8(acc)
    deq = dequantize_int8(q, s)
    return deq, acc - deq


def make_pod_allreduce(mesh, compress: bool = False, axis: str | None = None):
    """Mean-reduce dim 0 shards across ``axis`` (default: first mesh axis).

    Input is sharded ``P(axis)`` on dim 0; output has the same global shape
    with every shard holding the cross-pod mean. ``compress=True`` sends
    int8 against a shared pre-agreed scale (pmax of shard absmaxes) and
    accumulates in int32 (exact for <= 2**24 pods); ``compress=False`` is an
    exact f32 psum.
    """
    axis = axis or tuple(mesh.axis_names)[0]
    n = int(mesh.shape[axis])

    def reduce_shard(x):
        if not compress:
            return jax.lax.psum(x, axis) / n
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q, _ = quantize_int8(x, scale)   # shared pre-agreed grid
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return total.astype(jnp.float32) * scale / n

    return shard_map(
        reduce_shard, mesh=mesh, in_specs=P(axis), out_specs=P(axis),
        check_rep=False,
    )
