"""Sharding policy + PartitionSpec rule trees for the model/opt/batch structs.

``Policy`` names which mesh axes carry which kind of parallelism:

* ``dp``   — pure data parallelism (batch dim of activations; grads
  all-reduced, params replicated unless also in ``fsdp``).
* ``fsdp`` — ZeRO-3 style parameter/optimizer sharding axes. Params are
  *stored* sharded along these axes; the ``gather_params`` hint
  (:mod:`repro.dist.hints`) re-gathers them at use.
* ``tp``   — tensor parallelism (Megatron-style): heads / ff / vocab dims.
  ``None`` disables TP; a tuple (e.g. ``("data", "model")``) gives 2-D
  weight-stationary TP for large-model decode.
* ``shard_seq`` / ``sp`` — sequence (Megatron-SP) sharding of activations /
  KV caches along ``sp``.

Presets (``Policy.recommended``) encode the measured §Perf findings:
small-model training wants pure DP over every axis (no TP collectives on the
critical path); large-model training wants TP over ``model`` + FSDP over the
remaining axes; large-model decode wants 2-D weight-stationary TP with
sequence-sharded KV; small-model decode wants 1-D TP (weights fit, latency
dominated by the all-gather of tiny activations).

Every rule here is *advisory to GSPMD*: a spec that does not divide a dim is
dropped (conservative replication) so one odd head count can never turn a
dry-run into a shape error.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

Axes = tuple[str, ...]

# Parameter leaves that stay replicated everywhere: norm scales, tiny bias /
# gate vectors, SSM scalars-per-channel. They are O(d_model) — sharding them
# buys nothing and costs a gather per use.
_REPLICATED_NAMES = frozenset(
    {"scale", "bias", "if_bias", "dt_bias", "d_skip", "a_log", "conv", "len"}
)
_BLOCK_KEY = re.compile(r"^(b|x)\d+$")
_MLP_KEY = re.compile(r"^m\d+$")
# Large-model thresholds (total params) for the recommended presets.
_TRAIN_TP_THRESHOLD = 16e9
_DECODE_2D_THRESHOLD = 100e9


@dataclasses.dataclass(frozen=True)
class Policy:
    """Axis assignment for one (arch, shape, mesh) cell.

    Fields are mesh axis names: ``dp``/``fsdp`` are tuples, ``tp``/``sp``
    are a single axis name, a tuple (multi-axis TP), or ``None``.
    ``dataclasses.asdict`` must stay JSON-serializable (dry-run records).
    """

    dp: Axes = ()
    tp: str | Axes | None = None
    fsdp: Axes = ()
    shard_seq: bool = False
    sp: str | Axes | None = None

    @classmethod
    def for_mesh(cls, mesh, kind: str = "train") -> "Policy":
        """Default policy: TP over the ``model`` axis (when present), DP over
        everything else, FSDP==DP for training, no FSDP for serving kinds."""
        axes = tuple(mesh.axis_names)
        model = "model" if "model" in axes else None
        rest = tuple(a for a in axes if a != model)
        return cls(
            dp=rest,
            tp=model,
            fsdp=rest if kind == "train" else (),
            shard_seq=False,
            sp=model,
        )

    @classmethod
    def recommended(cls, cfg, mesh, mode: str) -> "Policy":
        """Hillclimbed presets keyed on model scale and execution mode.

        * train, small  (< 16e9 params): pure DP over *all* axes — no TP
          collectives; grads all-reduce once per step.
        * train, large: TP over ``model`` + FSDP/DP over the rest.
        * decode, small (< 100e9): 1-D TP over ``model``, DP over the rest.
        * decode, large: 2-D weight-stationary TP over every axis,
          sequence-sharded KV (``shard_seq``), no DP/FSDP.
        """
        axes = tuple(mesh.axis_names)
        model = "model" if "model" in axes else axes[-1]
        rest = tuple(a for a in axes if a != model)
        total, _ = cfg.param_count()

        if mode in ("train", "prefill"):
            if total < _TRAIN_TP_THRESHOLD:
                return cls(dp=axes, tp=None, fsdp=axes, shard_seq=False, sp=model)
            return cls(dp=rest, tp=model, fsdp=rest, shard_seq=False, sp=model)
        # decode / long
        if total < _DECODE_2D_THRESHOLD:
            return cls(dp=rest, tp=model, fsdp=(), shard_seq=False, sp=model)
        return cls(dp=(), tp=axes, fsdp=(), shard_seq=True, sp=model)


# --------------------------------------------------------------------- rules


def _axes_of(entry) -> Axes:
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def _entry(entry):
    """Normalize a spec entry: drop empty tuples, unwrap singletons."""
    axes = _axes_of(entry)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _sanitize(spec: tuple, shape: tuple[int, ...], mesh_shape: dict) -> P:
    """Drop spec entries that do not divide their dim or reuse an axis."""
    used: set[str] = set()
    out = []
    for dim, entry in zip(shape, spec):
        axes = tuple(a for a in _axes_of(entry) if a not in used)
        size = int(np.prod([mesh_shape[a] for a in axes])) if axes else 1
        if not axes or size <= 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(_entry(axes))
    return P(*out)


def _core_spec(path_names: tuple[str, ...], name: str, ndim: int, pol: Policy):
    """PartitionSpec entries for one *unstacked* parameter leaf.

    ``path_names`` is the full dict path (so MoE ``wo`` (E,F,D) can be told
    apart from attention ``wo`` (H,hd,D) by its ``m<i>`` parent).
    """
    t = _entry(pol.tp)
    f = _entry(pol.fsdp)
    if name in _REPLICATED_NAMES or ndim <= 1:
        return (None,) * ndim
    if name == "embed":                       # (V, D): vocab->tp, d->fsdp
        return (t, f)
    if name == "lm_head":                     # (D, V)
        return (f, t)
    in_mlp = any(_MLP_KEY.match(p) for p in path_names) or "mlp" in path_names \
        or "shared" in path_names
    if in_mlp:
        if name == "router":                  # (D, E)
            return (f, None)
        if ndim == 3:                         # MoE experts (E, D, F)/(E, F, D)
            return (t, f, None) if name in ("wi", "wg") else (t, None, f)
        # dense / shared-expert MLP (d, ff) / (ff, d)
        return (f, t) if name in ("wi", "wg") else (t, f)
    # attention / ssm / xlstm blocks
    if name in ("wq", "wk", "wv"):
        return (f, t, None) if ndim == 3 else (f, t)
    if name == "wo":                          # (H, hd, D)
        return (t, None, f)
    if name in ("bq", "bk", "bv"):            # (H, hd)
        return (t, None)
    if name in ("up", "wx", "in_proj", "wi", "wg"):   # (D, inner)
        return (f, t)
    if name in ("down", "out_proj"):          # (inner, D)
        return (t, f)
    if name == "r":                           # slstm recurrent (H, hd, 4hd)
        return (t, None, None)
    if name in ("wif", "x_proj"):             # (inner, small)
        return (f, None)
    return (None,) * ndim


def _path_names(path) -> tuple[str, ...]:
    out = []
    for k in path:
        key = getattr(k, "key", None)
        out.append(str(key if key is not None else getattr(k, "idx", k)))
    return tuple(out)


def _leaf_spec(path, leaf, pol: Policy, mesh_shape: dict) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    ndim = len(leaf.shape)
    stacked = bool(names) and names[0] in ("layers", "encoder")
    core_ndim = ndim - 1 if stacked else ndim
    spec = _core_spec(names, name, core_ndim, pol)
    if stacked:
        spec = (None,) + tuple(spec)   # never shard the scan/period axis
    return _sanitize(spec, leaf.shape, mesh_shape)


def param_shardings(mesh, tree: Any, pol: Policy) -> Any:
    """NamedSharding tree for a params (or opt m/v) struct.

    Works on the stacked full-model struct (``params_struct``) and on the
    per-period subtree seen inside ``lax.scan`` (used by the gather hint).
    """
    import jax

    shape = dict(mesh.shape)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _leaf_spec(path, leaf, pol, shape)),
        tree,
    )


def param_specs(tree: Any, pol: Policy, mesh_shape: dict) -> Any:
    """Like :func:`param_shardings` but raw ``PartitionSpec`` leaves (for
    ``with_sharding_constraint`` inside a mesh context)."""
    import jax

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, pol, mesh_shape), tree
    )


# ------------------------------------------------------------ batch / cache


def _dp_entry(pol: Policy):
    return _entry(pol.dp)


def batch_specs(cfg, pol: Policy, b_sds: dict | None = None) -> dict[str, P]:
    """PartitionSpec per batch tensor (train / prefill structs).

    Batch dim shards over ``dp``; with ``shard_seq`` the sequence dim shards
    over ``sp`` (Megatron-SP enters the stack already sequence-sharded).
    """
    dp = _dp_entry(pol)
    sp = _entry(pol.sp) if pol.shard_seq else None
    rank = {"tokens": 2, "labels": 2, "embeds": 3, "frames": 3}
    if b_sds is not None:
        keys = list(b_sds)
    else:
        keys = (["embeds"] if cfg.frontend == "embed" else ["tokens"]) + (
            ["frames"] if cfg.encoder_layers else []
        ) + ["labels"]
    out = {}
    for k in keys:
        r = rank.get(k, 2)
        spec = (dp, sp) + (None,) * (r - 2)
        out[k] = P(*spec[:r])
    return out


def cache_spec_tree(cfg, cache_sds: Any, pol: Policy, mesh) -> Any:
    """NamedSharding tree for the decode-cache struct from ``init_cache``.

    Leaves carry a leading period (scan) axis that never shards. The batch
    dim shards over ``dp``; attention K/V additionally shard the sequence
    dim over ``sp`` when ``shard_seq`` and the KV-head dim over ``tp``;
    recurrent states (mamba/xlstm) shard their channel dim over ``tp``.
    """
    import jax

    shape = dict(mesh.shape)
    dp = None if pol.shard_seq else _dp_entry(pol)
    t = _entry(pol.tp)
    sp = _entry(pol.sp) if pol.shard_seq else None

    def leaf(path, l):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = len(l.shape)
        if name == "len" or nd <= 1:
            return NamedSharding(mesh, P())
        if name in ("k", "v") and nd == 5:       # (periods, B, S, KV, hd)
            spec = (None, dp, sp, t, None)
        elif nd >= 3:                            # recurrent state (periods, B, C, ...)
            spec = (None, dp, t) + (None,) * (nd - 3)
        else:                                    # (periods, B)
            spec = (None, dp)
        return NamedSharding(mesh, _sanitize(spec, l.shape, shape))

    return jax.tree_util.tree_map_with_path(leaf, cache_sds)
