"""Serving engine: continuous batching over a fixed slot pool.

Requests queue in; free slots prefill (one request at a time here — the
multi-pod path shards prefill over the mesh) and then join the batched
decode step. Each decode step runs the whole slot pool through
``decode_step`` + the radix-CDF sampler; finished slots (EOS/max-len) are
recycled. KV caches live per-slot and are scatter-updated in the batch
dimension — the CPU-scale stand-in for paged attention.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig

from .sampler import TokenSampler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    eos: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params: Any, cfg: ModelConfig, n_slots: int = 8,
                 max_seq: int = 512, sampler: TokenSampler | None = None):
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.sampler = sampler or TokenSampler(n_slots=n_slots, use_pallas=False)
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * n_slots
        self.cache = init_cache(cfg, n_slots, max_seq)
        self.pos = np.zeros(n_slots, np.int32)
        self.last_tok = np.zeros(n_slots, np.int32)
        self.steps = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.n_slots):
            if self.slots[s] is None and self.queue:
                req = self.queue.popleft()
                self.slots[s] = req
                # prefill this request alone, then splice its cache into the
                # slot position of the batched cache
                batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
                logits, cache1, _ = prefill(
                    self.params, self.cfg, batch, max_seq=self.max_seq
                )
                tok = self.sampler.sample(logits, np.array([s]))[0]

                def splice(big, one):
                    # leaves without a slot dim (e.g. stacked 'len' counters)
                    if one.ndim < 2 or big.shape[1] != self.n_slots:
                        return big
                    return big.at[:, s].set(one[:, 0])

                self.cache = jax.tree.map(splice, self.cache, cache1)
                self.pos[s] = len(req.prompt)
                self.last_tok[s] = tok
                req.out.append(int(tok))

    def _retire(self) -> None:
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            if (
                len(req.out) >= req.max_new
                or (req.eos is not None and req.out and req.out[-1] == req.eos)
                or self.pos[s] >= self.max_seq - 1
            ):
                req.done = True
                self.slots[s] = None

    def step(self) -> None:
        self._admit()
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        # attention_decode scatters at per-row pos, so idle slots simply
        # overwrite their own stale cell; only active slots are read out.
        logits, new_cache = decode_step(
            self.params,
            self.cfg,
            self.cache,
            jnp.asarray(self.last_tok),
            jnp.asarray(self.pos),
        )
        self.cache = new_cache
        act = np.asarray(active)
        toks = self.sampler.sample(logits[act], act)
        for i, s in enumerate(active):
            tok = int(toks[i])
            self.slots[s].out.append(tok)
            self.last_tok[s] = tok
            self.pos[s] += 1
        self._retire()
        self.steps += 1

    def run(self, max_steps: int = 1000) -> None:
        while (self.queue or any(self.slots)) and self.steps < max_steps:
            self.step()
