"""Serving engine: continuous batching over a fixed slot pool.

Requests queue in; free slots prefill (one request at a time here — the
multi-pod path shards prefill over the mesh) and then join the batched
decode step. Each decode step runs the whole slot pool through
``decode_step`` + the radix-CDF sampler; finished slots (EOS/max-len) are
recycled. KV caches live per-slot and are scatter-updated in the batch
dimension — the CPU-scale stand-in for paged attention.

Multi-tenant path: a request may carry its own static categorical
(``Request.prior`` — draft prior, per-client mixture, per-cell density).
Such requests bypass the model entirely: on admit the prior is inserted
into a :class:`~repro.serve.sampler.PooledForestSampler`'s size-class
arena, every step drains ALL prior-backed slots with one batched kernel
launch per touched size class, and retirement evicts the tenant (slot
handles are versioned, so churn can never sample a stale distribution).
With ``params=None`` the engine serves pure categorical traffic — the
paper's millions-of-users scenario with no LM in the loop.

2-D path: a request may instead carry ``Request.prior2d`` — an
environment/density map sampled as row-marginal x per-row conditional
(the paper's Sec. 5 application). All such requests share ONE
:class:`~repro.serve.sampler.SpatialSampler` (the map is a shared static
asset, like the model weights; per-request maps belong in the pool path as
flattened priors), every step drains ALL 2-D slots with one bulk
``sample_map`` call, and each emitted "token" is the flat texel id.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.robust.errors import RequestError, ServingError
from repro.robust.validate import classify_weights

from .sampler import PooledForestSampler, SpatialSampler, TokenSampler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    eos: int | None = None
    prior: np.ndarray | None = None  # per-request categorical (pool path)
    # sampling method for the prior's pool slot: "forest" (monotone,
    # QMC-safe), "alias" (packed O(1) tables, bulk PRNG traffic), or
    # "auto" — let the prior sampler pick by its stream kind
    method: str = "auto"
    # 2-D map request: the engine's SHARED environment/density map (every
    # prior2d request must carry the same map; tokens are flat texel ids)
    prior2d: Any | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # set when the engine retires the request on a fault instead of
    # serving it (``on_fault="retire"``): "<code>: <detail>"
    error: str | None = None


class ServeEngine:
    """``on_fault`` picks the per-request failure semantics: ``"raise"``
    (default, the historical behavior — a malformed request surfaces as the
    structured exception from :meth:`submit`/:meth:`step`) or ``"retire"``
    — :meth:`step` isolates the failure to the offending request, retiring
    it with ``Request.error = "<code>: <detail>"`` while every other live
    slot keeps serving. Either way, malformed priors are caught with the
    :mod:`repro.robust.errors` taxonomy at :meth:`submit` time when the
    admission policy is strict, never as a mid-``step`` crash."""

    def __init__(self, params: Any, cfg: ModelConfig | None, n_slots: int = 8,
                 max_seq: int = 512, sampler: TokenSampler | None = None,
                 prior_sampler: PooledForestSampler | None = None,
                 spatial_sampler: SpatialSampler | None = None,
                 on_fault: str = "raise"):
        if on_fault not in ("raise", "retire"):
            raise ValueError(f"on_fault must be 'raise' or 'retire', got {on_fault!r}")
        self.params = params
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.on_fault = on_fault
        self.sampler = sampler or TokenSampler(n_slots=n_slots, use_pallas=False)
        self.prior_sampler = prior_sampler
        self.prior_handles: dict[int, Any] = {}  # slot -> pool Handle
        self.spatial_sampler = spatial_sampler
        self.spatial_slots: set[int] = set()  # slots on the 2-D map drain
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * n_slots
        if params is not None:
            from repro.models import init_cache  # lazy: priors-only engines
                                                 # never touch the model layer
            self.cache = init_cache(cfg, n_slots, max_seq)
        else:
            self.cache = None
        self.pos = np.zeros(n_slots, np.int32)
        self.last_tok = np.zeros(n_slots, np.int32)
        self.steps = 0

    def _prior_policy(self) -> str:
        return self.prior_sampler.pool.policy if self.prior_sampler else "reject"

    def _validate(self, req: Request) -> None:
        """Submit-time structural validation: wrong dtype / negative
        entries / non-finite mass / shape mismatches are rejected HERE,
        with the structured taxonomy, not discovered as a mid-``step``
        exception. Weight-*value* violations defer to the prior pool's
        admission policy when it is lenient (clamp/quarantine repair at
        admit instead)."""
        if req.prior is not None:
            try:
                _, code = classify_weights(req.prior)
            except ServingError as e:
                raise RequestError(
                    f"request {req.rid}: prior {e.code}: {e}"
                ) from None
            if code is not None and self._prior_policy() == "reject":
                raise RequestError(f"request {req.rid}: prior {code}")
        if req.prior2d is not None:
            try:
                rows = [np.asarray(r, np.float64) for r in req.prior2d]
            except (TypeError, ValueError) as e:
                raise RequestError(
                    f"request {req.rid}: prior2d bad_dtype: {e}"
                ) from None
            if not rows or any(r.ndim != 1 or r.size == 0 for r in rows):
                raise RequestError(
                    f"request {req.rid}: prior2d bad_shape: want non-empty "
                    "1-D rows"
                )
            for r in rows:
                _, code = classify_weights(r, allow_zero_total=True)
                if code is not None:
                    raise RequestError(f"request {req.rid}: prior2d {code}")
            if self.spatial_sampler is not None:
                have = self.spatial_sampler.map.rows_raw
                if len(rows) != len(have) or any(
                    a.shape != b.shape for a, b in zip(rows, have)
                ):
                    raise RequestError(
                        f"request {req.rid}: prior2d map_mismatch: shape "
                        "differs from the engine's shared map"
                    )

    def submit(self, req: Request) -> None:
        if req.prior is not None and req.prior2d is not None:
            raise RequestError("a request carries prior OR prior2d, not both")
        if req.prior is None and req.prior2d is None and self.params is None:
            raise RequestError(
                "engine has no model (params=None); submit prior-backed "
                "requests only"
            )
        self._validate(req)
        self.queue.append(req)

    def _same_map(self, img) -> bool:
        rows = [np.asarray(r, np.float64) for r in img]
        have = self.spatial_sampler.map.rows_raw
        return len(rows) == len(have) and all(
            a.shape == b.shape and np.array_equal(a, b)
            for a, b in zip(rows, have)
        )

    def _fail_request(self, s: int, err: Exception) -> None:
        """Isolate one request's fault (``on_fault="retire"``): the request
        retires with a structured ``error`` result; the slot frees; every
        other live slot is untouched."""
        req = self.slots[s]
        if req is not None:
            req.error = f"{getattr(err, 'code', 'error')}: {err}"
            req.done = True
        self.slots[s] = None
        self.prior_handles.pop(s, None)
        self.spatial_slots.discard(s)

    def _admit_spatial(self, admitted: list[tuple[int, Request]]) -> None:
        """2-D admission wave: the engine's map is a shared static asset —
        the first ``prior2d`` request instantiates the
        :class:`SpatialSampler`; later requests must carry the identical
        map (a per-request map belongs in the pool path). The wave draws
        its first texels in one bulk ``sample_map`` drain."""
        if self.spatial_sampler is None:
            self.spatial_sampler = SpatialSampler(
                admitted[0][1].prior2d, n_slots=self.n_slots,
                use_pallas=False,
            )
        kept = []
        for s, req in admitted:
            if not self._same_map(req.prior2d):
                err = RequestError(
                    f"request {req.rid}: prior2d differs from the engine's "
                    "shared map; per-request distributions go through "
                    "Request.prior (the pool path)"
                )
                if self.on_fault == "retire":
                    self._fail_request(s, err)
                    continue
                self.slots[s] = None
                raise err
            self.spatial_slots.add(s)
            kept.append((s, req))
        admitted = kept
        if not admitted:
            return
        slots = np.asarray([s for s, _ in admitted])
        toks = self.spatial_sampler.sample_flat(slots)
        for (s, req), tok in zip(admitted, toks):
            self.pos[s] = 0
            self.last_tok[s] = int(tok)
            req.out.append(int(tok))

    def _admit_priors(self, admitted: list[tuple[int, Request]]) -> None:
        """Prior-backed admission wave: no prefill, no KV — the whole wave
        joins the pool through the fused batched builder (one build launch
        per touched size class) and draws its first tokens in one batched
        drain."""
        if self.prior_sampler is None:
            self.prior_sampler = PooledForestSampler(
                n_slots=self.n_slots, use_pallas=False
            )
        try:
            hs = self.prior_sampler.add_many(
                [r.prior for _, r in admitted],
                method=[r.method for _, r in admitted],
            )
        except ValueError:
            if self.on_fault != "retire":
                for s, _ in admitted:
                    self.slots[s] = None
                raise
            # isolate: re-admit one by one, retiring only the bad tenants
            # (their co-tenants still get the same pool rows and samples)
            kept, hs = [], []
            for s, req in admitted:
                try:
                    hs.append(self.prior_sampler.add(req.prior,
                                                     method=req.method))
                    kept.append((s, req))
                except ValueError as e:
                    self._fail_request(s, e)
            admitted = kept
            if not admitted:
                return
        slots = np.asarray([s for s, _ in admitted])
        for (s, _), h in zip(admitted, hs):
            self.prior_handles[s] = h
        toks = self.prior_sampler.sample(hs, slots)
        for (s, req), tok in zip(admitted, toks):
            self.pos[s] = 0
            self.last_tok[s] = int(tok)
            req.out.append(int(tok))

    def _admit(self) -> None:
        priors: list[tuple[int, Request]] = []
        spatial: list[tuple[int, Request]] = []
        for s in range(self.n_slots):
            if self.slots[s] is None and self.queue:
                req = self.queue.popleft()
                self.slots[s] = req
                if req.prior is not None:
                    priors.append((s, req))
                    continue
                if req.prior2d is not None:
                    spatial.append((s, req))
                    continue
                from repro.models import prefill

                # prefill this request alone, then splice its cache into the
                # slot position of the batched cache
                batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
                logits, cache1, _ = prefill(
                    self.params, self.cfg, batch, max_seq=self.max_seq
                )
                tok = self.sampler.sample(logits, np.array([s]))[0]

                def splice(big, one):
                    # leaves without a slot dim (e.g. stacked 'len' counters)
                    if one.ndim < 2 or big.shape[1] != self.n_slots:
                        return big
                    return big.at[:, s].set(one[:, 0])

                self.cache = jax.tree.map(splice, self.cache, cache1)
                self.pos[s] = len(req.prompt)
                self.last_tok[s] = tok
                req.out.append(int(tok))
        if priors:
            self._admit_priors(priors)
        if spatial:
            self._admit_spatial(spatial)

    def _retire(self) -> None:
        for s, req in enumerate(self.slots):
            if req is None:
                continue
            if (
                len(req.out) >= req.max_new
                or (req.eos is not None and req.out and req.out[-1] == req.eos)
                # max_seq is a KV budget; prior/2-D-backed slots hold no KV
                or (s not in self.prior_handles
                    and s not in self.spatial_slots
                    and self.pos[s] >= self.max_seq - 1)
            ):
                req.done = True
                self.slots[s] = None
                h = self.prior_handles.pop(s, None)
                if h is not None:
                    try:
                        self.prior_sampler.remove(h)
                    except ValueError:
                        # already evicted through an outside reference —
                        # the slot still frees either way
                        if self.on_fault != "retire":
                            raise
                # 2-D slots hold no pool handle — the map is shared; just
                # leave the drain set (slot streams keep their counters)
                self.spatial_slots.discard(s)

    def step(self) -> None:
        self._admit()
        active = [s for s, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        model_slots = [
            s for s in active
            if s not in self.prior_handles and s not in self.spatial_slots
        ]
        prior_slots = [s for s in active if s in self.prior_handles]
        spatial_slots = [s for s in active if s in self.spatial_slots]
        if model_slots:
            from repro.models import decode_step

            # attention_decode scatters at per-row pos, so idle slots simply
            # overwrite their own stale cell; only active slots are read out.
            logits, new_cache = decode_step(
                self.params,
                self.cfg,
                self.cache,
                jnp.asarray(self.last_tok),
                jnp.asarray(self.pos),
            )
            self.cache = new_cache
            act = np.asarray(model_slots)
            toks = self.sampler.sample(logits[act], act)
            for i, s in enumerate(model_slots):
                tok = int(toks[i])
                self.slots[s].out.append(tok)
                self.last_tok[s] = tok
                self.pos[s] += 1
        if prior_slots and self.on_fault == "retire":
            # pre-drain screen: a slot whose pool handle went stale (e.g.
            # evicted through an outside pool reference) retires with a
            # structured error instead of poisoning the batched drain
            live = []
            for s in prior_slots:
                try:
                    self.prior_sampler.pool._check(self.prior_handles[s])
                    live.append(s)
                except ValueError as e:
                    self._fail_request(s, e)
            prior_slots = live
        if prior_slots:
            # the batched drain: every prior-backed slot, one stream-aware
            # pool call (device-side QMC counters, one launch per size class)
            hs = [self.prior_handles[s] for s in prior_slots]
            toks = self.prior_sampler.sample(hs, np.asarray(prior_slots))
            for i, s in enumerate(prior_slots):
                tok = int(toks[i])
                self.slots[s].out.append(tok)
                self.last_tok[s] = tok
                # pos stays frozen at 0: prior slots hold no KV, and pos
                # doubles as decode_step's scatter index for EVERY row — a
                # drifting pos would walk a prior slot's writes across (and
                # eventually past) the max_seq cache budget.
        if spatial_slots:
            # the 2-D bulk drain: every map-backed slot resolves its next
            # 2-D stream point through one sample_map call (marginal descent
            # + one conditional launch per touched size class); the emitted
            # token is the flat texel id. pos frozen at 0, as above.
            toks = self.spatial_sampler.sample_flat(np.asarray(spatial_slots))
            for i, s in enumerate(spatial_slots):
                tok = int(toks[i])
                self.slots[s].out.append(tok)
                self.last_tok[s] = tok
        self._retire()
        self.steps += 1

    def run(self, max_steps: int = 1000) -> None:
        while (self.queue or any(self.slots)) and self.steps < max_steps:
            self.step()

    # ---------------------------------------------------------- persistence

    @staticmethod
    def _req_state(r: Request | None):
        if r is None:
            return None
        return dict(
            rid=r.rid, prompt=np.asarray(r.prompt), max_new=r.max_new,
            eos=r.eos,
            prior=None if r.prior is None else np.asarray(r.prior, np.float64),
            method=r.method,
            prior2d=None if r.prior2d is None
            else [np.asarray(row, np.float64) for row in r.prior2d],
            out=list(r.out), done=r.done, error=r.error,
        )

    @staticmethod
    def _req_restore(d) -> Request | None:
        if d is None:
            return None
        return Request(
            rid=int(d["rid"]), prompt=np.asarray(d["prompt"]),
            max_new=int(d["max_new"]), eos=d["eos"],
            prior=None if d["prior"] is None else np.asarray(d["prior"]),
            method=d["method"],
            prior2d=None if d["prior2d"] is None
            else [np.asarray(row) for row in d["prior2d"]],
            out=[int(t) for t in d["out"]], done=bool(d["done"]),
            error=d["error"],
        )

    def snapshot(self) -> dict:
        """Full engine serving state: slot/queue requests, per-slot
        positions, pool handles, every sampler's exact stream state, and
        the KV cache leaves — everything except the model parameters
        themselves (pass those back to :meth:`restore`). Committed through
        :func:`repro.ckpt.save_state`, a killed process resumes with
        bit-identical subsequent drains."""
        cache_leaves = None
        if self.cache is not None:
            cache_leaves = [np.asarray(x)
                            for x in jax.tree_util.tree_leaves(self.cache)]
        return dict(
            kind="serve_engine",
            n_slots=self.n_slots, max_seq=self.max_seq,
            on_fault=self.on_fault,
            has_model=self.params is not None,
            steps=self.steps,
            pos=self.pos.copy(), last_tok=self.last_tok.copy(),
            queue=[self._req_state(r) for r in self.queue],
            slots=[self._req_state(r) for r in self.slots],
            prior_handles={int(s): tuple(h)
                           for s, h in self.prior_handles.items()},
            spatial_slots=set(self.spatial_slots),
            sampler=self.sampler.snapshot(),
            prior_sampler=None if self.prior_sampler is None
            else self.prior_sampler.snapshot(),
            spatial_sampler=None if self.spatial_sampler is None
            else self.spatial_sampler.snapshot(),
            cache=cache_leaves,
        )

    @classmethod
    def restore(cls, state: dict, params: Any = None,
                cfg: ModelConfig | None = None) -> "ServeEngine":
        """Rebuild an engine from :meth:`snapshot` output. A model-backed
        snapshot needs the (unsnapshotted) ``params``/``cfg`` passed back;
        pool handles stay valid because the pool snapshot carries its
        version counters."""
        if state.get("kind") != "serve_engine":
            raise ValueError(f"not a ServeEngine snapshot: {state.get('kind')!r}")
        if state["has_model"] and params is None:
            raise ValueError("snapshot was model-backed: pass params and cfg")
        eng = cls(params if state["has_model"] else None, cfg,
                  n_slots=int(state["n_slots"]), max_seq=int(state["max_seq"]),
                  on_fault=state.get("on_fault", "raise"))
        eng.steps = int(state["steps"])
        eng.pos = np.asarray(state["pos"], np.int32).copy()
        eng.last_tok = np.asarray(state["last_tok"], np.int32).copy()
        eng.queue = deque(cls._req_restore(d) for d in state["queue"])
        eng.slots = [cls._req_restore(d) for d in state["slots"]]
        from repro.pool import Handle  # lazy: keeps import edges thin

        eng.prior_handles = {
            int(s): Handle(int(h[0]), int(h[1]), int(h[2]), int(h[3]), h[4])
            for s, h in state["prior_handles"].items()
        }
        eng.spatial_slots = {int(s) for s in state["spatial_slots"]}
        eng.sampler = TokenSampler.restore(state["sampler"])
        if state["prior_sampler"] is not None:
            eng.prior_sampler = PooledForestSampler.restore(
                state["prior_sampler"]
            )
        if state["spatial_sampler"] is not None:
            eng.spatial_sampler = SpatialSampler.restore(
                state["spatial_sampler"]
            )
        if state["cache"] is not None and eng.cache is not None:
            treedef = jax.tree_util.tree_structure(eng.cache)
            eng.cache = jax.tree_util.tree_unflatten(
                treedef, [jnp.asarray(x) for x in state["cache"]]
            )
        return eng
