"""Token samplers for serving: the paper's monotone inversion vs the Alias
Method, with per-slot QMC uniform streams.

Modes:
  * ``inverse_qmc``  — fused softmax->CDF + tiled inverse (kernels), uniforms
    from per-slot scrambled van-der-Corput streams. Monotone warp => the
    stream's stratification survives (paper Sec. 3); best-of-n decode from
    one distribution provably covers the distribution better (benchmark
    ``benchmarks/serving_diversity.py``).
  * ``inverse_rng``  — same mapping, PRNG uniforms (the MC baseline).
  * ``alias``        — Walker/Vose per-row alias tables (serial build, non-
    monotone mapping; the paper's antagonist, kept for comparison).

QMC streams come in a host/device pair sharing ONE exact 24-bit fixed-point
pipeline (``core.lds.qmc_bits24*``): per-slot counters, Cranley-Patterson
offsets quantized to the 2^-24 grid, base-2 radical inverse by bit reversal,
rotation as integer add mod 2^24. :class:`QmcStreams` is the numpy oracle;
:class:`DeviceQmcStreams` keeps the same state as jax arrays and advances it
inside one jitted program per drain, so the serving hot path
(:class:`PooledForestSampler` -> ``ForestPool.sample_streams`` -> the
stream-aware ``forest_sample_batched_streams`` kernel) mutates no host-side
bookkeeping at all — and the differential suite asserts the two are
bit-equal, counters and points, including duplicate slots in one drain.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_forest, sample_forest
from repro.core.alias import build_alias, sample_alias
from repro.core.cdf import normalize_weights, updated_weights
from repro.core.lds import (
    QMC_SCALE,
    qmc2_point,
    qmc2_point_np,
    qmc_bits24_np,
    qmc_offset_bits_np,
    qmc_point,
)
from repro.kernels import ops


class QmcStreams:
    """Per-slot low-discrepancy uniform streams with Cranley-Patterson
    rotations (slot-hash offsets keep slots decorrelated but stratified).

    The host-side oracle of the stream pair: pure numpy, one counter per
    slot, points drawn through the exact fixed-point pipeline shared with
    :class:`DeviceQmcStreams` (same seed => bit-equal points and counters).
    Serving hot paths should prefer the device twin; this class remains the
    reference for differential tests and host-only callers."""

    def __init__(self, n_slots: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.offset_bits = qmc_offset_bits_np(rng.random(n_slots))
        self.offsets = self.offset_bits.astype(np.float32) * QMC_SCALE
        self.counters = np.zeros(n_slots, np.uint32)

    def next(self, slots: np.ndarray | None = None) -> np.ndarray:
        """One stream point per requested slot occurrence. A slot repeated k
        times in one drain draws its next k *distinct* stream points (the
        j-th occurrence, in call order, advances to counter+j) and its
        counter advances by k — fancy-index ``counters[slots] += 1`` would
        collapse duplicate increments and hand every occurrence the same
        point (identical best-of-n candidates)."""
        if slots is None:
            slots = np.arange(len(self.offset_bits))
        slots = np.asarray(slots)
        rank = _occurrence_rank_np(slots)
        xi = qmc_bits24_np(
            self.counters[slots] + rank, self.offset_bits[slots]
        ).astype(np.float32) * QMC_SCALE
        np.add.at(self.counters, slots, 1)
        return xi

    def snapshot(self) -> dict:
        """Exact stream state (offset bits + counters): restoring it makes
        every subsequent draw bit-identical to an uninterrupted stream."""
        return dict(kind="qmc_streams",
                    offset_bits=self.offset_bits.copy(),
                    counters=self.counters.copy())

    @classmethod
    def restore(cls, state: dict) -> "QmcStreams":
        s = cls.__new__(cls)
        s.offset_bits = np.asarray(state["offset_bits"], np.uint32).copy()
        s.offsets = s.offset_bits.astype(np.float32) * QMC_SCALE
        s.counters = np.asarray(state["counters"], np.uint32).copy()
        return s


def _occurrence_rank_np(slots: np.ndarray) -> np.ndarray:
    """Per-occurrence rank of each slot within one drain (call order): the
    j-th occurrence of a slot gets rank j. Stable sort + searchsorted."""
    order = np.argsort(slots, kind="stable")
    sorted_slots = slots[order]
    first = np.searchsorted(sorted_slots, sorted_slots, side="left")
    rank = np.empty(len(slots), np.uint32)
    rank[order] = (np.arange(len(slots)) - first).astype(np.uint32)
    return rank


def _pow2_at_least(x: int, floor: int) -> int:
    p = max(int(floor), 1)
    while p < x:
        p <<= 1
    return p


@jax.jit
def _stream_prepass(counters: jax.Array, offset_bits: jax.Array,
                    slots: jax.Array):
    """Device twin of one ``QmcStreams.next`` drain, as a single program:
    per-occurrence rank (stable sort — identical to the host rank), per-lane
    rank-adjusted counters + offsets, the drawn points, and the advanced
    per-slot counters. Sentinel lanes (``slots < 0``, padding so drain
    shapes bucket to a few compiled programs) draw a dead point and advance
    nothing."""
    S = counters.shape[0]
    valid = slots >= 0
    # sentinels sort AFTER every real slot so they never perturb real ranks
    key = jnp.where(valid, slots, S)
    order = jnp.argsort(key, stable=True)
    sk = key[order]
    first = jnp.searchsorted(sk, sk, side="left")
    rank = jnp.zeros(slots.shape[0], jnp.uint32).at[order].set(
        (jnp.arange(slots.shape[0]) - first).astype(jnp.uint32)
    )
    sl = jnp.where(valid, slots, 0)
    ctr = jnp.where(valid, counters[sl] + rank, 0).astype(jnp.uint32)
    off = jnp.where(valid, offset_bits[sl], 0).astype(jnp.uint32)
    new_counters = counters.at[sl].add(valid.astype(jnp.uint32))
    return ctr, off, qmc_point(ctr, off), new_counters


class DeviceQmcStreams:
    """Device-side twin of :class:`QmcStreams`: the per-slot counters and
    Cranley-Patterson offset bits live as jax arrays, and a drain advances
    them inside :func:`_stream_prepass` — zero host-side counter mutation.
    Same seed as the host class => bit-equal offsets, counters, and points
    (both run the exact ``core.lds`` fixed-point pipeline).

    ``draw`` is the pool-facing protocol: it returns the per-lane
    rank-adjusted ``(counter, offset_bits, xi)`` arrays that thread into the
    stream-aware drain kernel (which recomputes the very same ``xi``
    in-kernel). ``next`` matches the host API for standalone callers."""

    def __init__(self, n_slots: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.offset_bits = jnp.asarray(qmc_offset_bits_np(rng.random(n_slots)))
        self.counters = jnp.zeros(n_slots, jnp.uint32)

    @property
    def n_slots(self) -> int:
        return int(self.offset_bits.shape[0])

    @property
    def offsets(self) -> np.ndarray:
        return np.asarray(self.offset_bits).astype(np.float32) * QMC_SCALE

    def draw(self, slots) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Advance every requested slot occurrence and return the per-lane
        stream state ``(counter, offset_bits, xi)``, each (Q,) on device.
        Drain lengths are padded (power-of-two, floor 64, sentinel slots) so
        churning batch sizes reuse a logarithmic number of programs."""
        slots = np.asarray(slots)
        Q = len(slots)
        qpad = _pow2_at_least(Q, 64)
        padded = np.full(qpad, -1, np.int32)
        padded[:Q] = slots.astype(np.int32)
        ctr, off, xi, self.counters = _stream_prepass(
            self.counters, self.offset_bits, jnp.asarray(padded)
        )
        return ctr[:Q], off[:Q], xi[:Q]

    def next(self, slots: np.ndarray | None = None) -> np.ndarray:
        """Host-API-compatible drain (returns the points as numpy)."""
        if slots is None:
            slots = np.arange(self.n_slots)
        return np.asarray(self.draw(slots)[2])

    def snapshot(self) -> dict:
        return dict(kind="device_qmc_streams",
                    offset_bits=np.asarray(self.offset_bits),
                    counters=np.asarray(self.counters))

    @classmethod
    def restore(cls, state: dict) -> "DeviceQmcStreams":
        s = cls.__new__(cls)
        s.offset_bits = jnp.asarray(np.asarray(state["offset_bits"], np.uint32))
        s.counters = jnp.asarray(np.asarray(state["counters"], np.uint32))
        return s


class Qmc2Streams:
    """Per-slot 2-D low-discrepancy streams: the host oracle of the 2-D
    stream pair. Dimension u is the base-2 radical inverse (Sobol' dim 0),
    dimension v is Sobol' dim 1 — the exact 24-bit integer pipeline of
    ``core.lds.qmc2_*`` — with independent per-slot Cranley-Patterson
    rotations per dimension. One counter per slot drives both dimensions
    (a 2-D stream point is ONE sequence element; advancing dimensions
    separately would desynchronize the pair and destroy the 2-D
    stratification). Same seed as :class:`DeviceQmc2Streams` => bit-equal
    offsets, counters, and points."""

    def __init__(self, n_slots: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.offset_u = qmc_offset_bits_np(rng.random(n_slots))
        self.offset_v = qmc_offset_bits_np(rng.random(n_slots))
        self.counters = np.zeros(n_slots, np.uint32)

    def next(self, slots: np.ndarray | None = None):
        """One 2-D stream point per requested slot occurrence (duplicate
        slots get distinct consecutive points — same rank protocol as
        :class:`QmcStreams`). Returns ``(u, v)`` float32 arrays."""
        if slots is None:
            slots = np.arange(len(self.offset_u))
        slots = np.asarray(slots)
        rank = _occurrence_rank_np(slots)
        ctr = self.counters[slots] + rank
        u, v = qmc2_point_np(ctr, self.offset_u[slots], self.offset_v[slots])
        np.add.at(self.counters, slots, 1)
        return u, v

    def snapshot(self) -> dict:
        return dict(kind="qmc2_streams",
                    offset_u=self.offset_u.copy(),
                    offset_v=self.offset_v.copy(),
                    counters=self.counters.copy())

    @classmethod
    def restore(cls, state: dict) -> "Qmc2Streams":
        s = cls.__new__(cls)
        s.offset_u = np.asarray(state["offset_u"], np.uint32).copy()
        s.offset_v = np.asarray(state["offset_v"], np.uint32).copy()
        s.counters = np.asarray(state["counters"], np.uint32).copy()
        return s


@jax.jit
def _stream_prepass2(counters: jax.Array, offset_u: jax.Array,
                     offset_v: jax.Array, slots: jax.Array):
    """Device twin of one ``Qmc2Streams.next`` drain as a single program —
    the 2-D sibling of :func:`_stream_prepass` (same sentinel-slot padding
    and duplicate-rank protocol, two rotated dimensions out)."""
    S = counters.shape[0]
    valid = slots >= 0
    key = jnp.where(valid, slots, S)
    order = jnp.argsort(key, stable=True)
    sk = key[order]
    first = jnp.searchsorted(sk, sk, side="left")
    rank = jnp.zeros(slots.shape[0], jnp.uint32).at[order].set(
        (jnp.arange(slots.shape[0]) - first).astype(jnp.uint32)
    )
    sl = jnp.where(valid, slots, 0)
    ctr = jnp.where(valid, counters[sl] + rank, 0).astype(jnp.uint32)
    ou = jnp.where(valid, offset_u[sl], 0).astype(jnp.uint32)
    ov = jnp.where(valid, offset_v[sl], 0).astype(jnp.uint32)
    u, v = qmc2_point(ctr, ou, ov)
    new_counters = counters.at[sl].add(valid.astype(jnp.uint32))
    return u, v, new_counters


class DeviceQmc2Streams:
    """Device-side twin of :class:`Qmc2Streams`: counters and both offset
    vectors live as jax arrays; a drain advances them inside
    :func:`_stream_prepass2` with zero host-side counter mutation. Same
    seed as the host class => bit-equal points and counters (the spatial
    differential suite pins this, duplicate slots included)."""

    def __init__(self, n_slots: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.offset_u = jnp.asarray(qmc_offset_bits_np(rng.random(n_slots)))
        self.offset_v = jnp.asarray(qmc_offset_bits_np(rng.random(n_slots)))
        self.counters = jnp.zeros(n_slots, jnp.uint32)

    @property
    def n_slots(self) -> int:
        return int(self.offset_u.shape[0])

    def draw(self, slots) -> tuple[jax.Array, jax.Array]:
        """Advance every requested slot occurrence; returns the ``(u, v)``
        point pair, each (Q,) float32 on device. Drain lengths pad to
        pow2 (floor 64, sentinel slots) exactly like the 1-D streams."""
        slots = np.asarray(slots)
        Q = len(slots)
        qpad = _pow2_at_least(Q, 64)
        padded = np.full(qpad, -1, np.int32)
        padded[:Q] = slots.astype(np.int32)
        u, v, self.counters = _stream_prepass2(
            self.counters, self.offset_u, self.offset_v, jnp.asarray(padded)
        )
        return u[:Q], v[:Q]

    def next(self, slots: np.ndarray | None = None):
        """Host-API-compatible drain (returns ``(u, v)`` as numpy)."""
        if slots is None:
            slots = np.arange(self.n_slots)
        u, v = self.draw(slots)
        return np.asarray(u), np.asarray(v)

    def snapshot(self) -> dict:
        return dict(kind="device_qmc2_streams",
                    offset_u=np.asarray(self.offset_u),
                    offset_v=np.asarray(self.offset_v),
                    counters=np.asarray(self.counters))

    @classmethod
    def restore(cls, state: dict) -> "DeviceQmc2Streams":
        s = cls.__new__(cls)
        s.offset_u = jnp.asarray(np.asarray(state["offset_u"], np.uint32))
        s.offset_v = jnp.asarray(np.asarray(state["offset_v"], np.uint32))
        s.counters = jnp.asarray(np.asarray(state["counters"], np.uint32))
        return s


_STREAM_KINDS = {
    "qmc_streams": "QmcStreams",
    "device_qmc_streams": "DeviceQmcStreams",
    "qmc2_streams": "Qmc2Streams",
    "device_qmc2_streams": "DeviceQmc2Streams",
}


def restore_streams(state: dict):
    """Dispatch a stream snapshot back to its class by ``kind``."""
    if state is None:
        return None
    cls = globals()[_STREAM_KINDS[state["kind"]]]
    return cls.restore(state)


def _rng_state(rng):
    return None if rng is None else rng.bit_generator.state


def _rng_restore(state):
    if state is None:
        return None
    rng = np.random.default_rng()
    rng.bit_generator.state = state
    return rng


class SpatialSampler:
    """2-D serving sampler: ONE shared environment/density map
    (:class:`repro.spatial.Map2DSampler`) drained by per-slot 2-D QMC
    streams — the paper's env-map application behind the serving API.

    Each ``sample`` call draws one 2-D stream point per slot occurrence
    (``streams="qmc"``: the exact 24-bit Sobol' pair with device-side
    counters; ``streams="prng"``: a seeded PRNG baseline) and resolves the
    whole batch through :meth:`~repro.spatial.Map2DSampler.sample_map` —
    marginal descent on u, one batched conditional launch per touched size
    class on v. Both warps are monotone, so the 2-D stratification of the
    streams survives into texel space. :meth:`update` re-targets dirty map
    rows in place; slot streams keep their counters, exactly as the 1-D
    samplers do across distribution swaps."""

    def __init__(self, img, n_slots: int = 64, seed: int = 0,
                 streams: str = "qmc", device_streams: bool = True,
                 use_pallas: bool | None = None, **map_kwargs):
        from repro.spatial import Map2DSampler  # lazy: serve stays importable

        if streams not in ("qmc", "prng"):
            raise ValueError(f"streams must be 'qmc' or 'prng', got {streams!r}")
        self.map = Map2DSampler(img, use_pallas=use_pallas, **map_kwargs)
        self.stream_kind = streams
        self.device_streams = device_streams and streams == "qmc"
        if streams == "qmc":
            self.streams = (
                DeviceQmc2Streams(n_slots, seed) if device_streams
                else Qmc2Streams(n_slots, seed)
            )
            self.rng = None
        else:
            self.streams = None
            self.rng = np.random.default_rng(seed)

    def _points(self, slots: np.ndarray):
        if self.stream_kind == "prng":
            pts = self.rng.random((len(slots), 2)).astype(np.float32)
            return pts[:, 0], pts[:, 1]
        u, v = self.streams.next(np.asarray(slots)) if not self.device_streams \
            else self.streams.draw(np.asarray(slots))
        return np.asarray(u), np.asarray(v)

    def sample(self, slots: np.ndarray):
        """One (row, col) texel per slot occurrence."""
        u, v = self._points(np.asarray(slots))
        r, c, _, _ = self.map.sample_map((u, v))
        return r, c

    def sample_flat(self, slots: np.ndarray) -> np.ndarray:
        """One flat texel id per slot occurrence (the engine's token form)."""
        r, c = self.sample(slots)
        return self.map.flat_index(r, c)

    def update(self, delta_rows: dict, *, delta: bool = False) -> dict:
        """Patch dirty map rows in place (O(dirty rows); see
        :meth:`repro.spatial.Map2DSampler.update_map`)."""
        return self.map.update_map(delta_rows, delta=delta)

    def snapshot(self) -> dict:
        """Map rows + build config + exact stream state. Restore rebuilds
        the map deterministically (bit-identical arrays) and resumes the
        streams where they stopped; sharded maps restore single-device
        (the dist conformance suite pins build bit-identity across that)."""
        m = self.map
        return dict(
            kind="spatial_sampler",
            rows=[np.asarray(r, np.float64) for r in m.rows_raw],
            map_kwargs=dict(
                m_marginal=m.m_marginal, min_class=m.min_class,
                fallback_slack=m.fallback_slack, coalesce=m.coalesce,
                use_pallas=m.use_pallas, policy=m.policy,
            ),
            stream_kind=self.stream_kind,
            device_streams=self.device_streams,
            streams=None if self.streams is None else self.streams.snapshot(),
            rng=_rng_state(self.rng),
        )

    @classmethod
    def restore(cls, state: dict) -> "SpatialSampler":
        if state.get("kind") != "spatial_sampler":
            raise ValueError(f"not a SpatialSampler snapshot: {state.get('kind')!r}")
        img = np.stack([np.asarray(r, np.float64) for r in state["rows"]])
        s = cls(img, n_slots=1, streams=state["stream_kind"],
                device_streams=state["device_streams"], **state["map_kwargs"])
        s.streams = restore_streams(state["streams"])
        s.rng = _rng_restore(state["rng"])
        return s


class ForestSampler:
    """Shared-distribution serving sampler: ONE static distribution (draft
    prior, data mixture, env-map row), many draws per step — the paper's
    amortized workload behind a serving-shaped API.

    Builds the radix forest once at construction; every ``sample`` call
    inverts the CDF at the slots' QMC streams (monotone warp, so the
    stratification survives). ``sharded=True`` opts into the cell-partitioned
    :mod:`repro.dist.forest` path: guide cells are partitioned over the mesh
    data axis (``rebalance=True`` balances the partition by leaf occupancy
    for spiky priors) and each draw is resolved by its owning shard
    (bit-identical to the single-device path — the dist conformance suite
    gates that). :meth:`update_weights` swaps the distribution in place —
    the sharded path rebuilds only the shards whose windows changed, and the
    per-slot QMC streams continue uninterrupted."""

    def __init__(self, weights, m: int | None = None, sharded: bool = False,
                 mesh=None, n_slots: int = 64, seed: int = 0,
                 rebalance: bool = False, routed: bool = True):
        self._raw = np.asarray(weights, np.float64)
        w = normalize_weights(self._raw)
        m = m or max(len(w), 16)
        self.sharded = sharded
        # Owner-routed all-to-all bulk drain (default) vs the replicated
        # masked-psum oracle — identical draws; routed is the scaling path.
        self.routed = routed
        self.streams = QmcStreams(n_slots, seed)
        if sharded:
            from repro.dist import forest as DF  # lazy: serve stays importable

            self.forest, self.mesh = DF.build_forest_sharded_auto(
                jnp.asarray(w), m, mesh=mesh, rebalance=rebalance
            )
        else:
            self.mesh = None
            self.forest = build_forest(jnp.asarray(w), m)

    def update_weights(self, weights=None, *, delta=None) -> None:
        """In-place distribution update (new full weights, or a delta added
        to the current raw weights). Slot streams keep their counters, so a
        long-lived serving loop re-targets without a stratification reset."""
        self._raw, w = updated_weights(self._raw, weights, delta=delta)
        if self.sharded:
            from repro.dist import forest as DF

            self.forest = DF.update_forest_sharded(
                self.forest, jnp.asarray(w), mesh=self.mesh
            )
        else:
            self.forest = build_forest(jnp.asarray(w), self.forest.m)

    def sample(self, slots: np.ndarray) -> np.ndarray:
        xi = jnp.asarray(self.streams.next(slots))
        if self.sharded:
            from repro.dist import forest as DF

            return np.asarray(DF.sample_sharded(
                self.forest, xi, mesh=self.mesh, routed=self.routed
            ))
        return np.asarray(sample_forest(self.forest, xi))


class PooledForestSampler:
    """Multi-tenant serving sampler: thousands of per-request categoricals
    (draft priors, per-client mixtures, per-cell densities) in ONE
    :class:`repro.pool.ForestPool`, drained in bulk.

    The serving-shaped complement of :class:`ForestSampler` (one shared
    distribution, many draws): here every request owns its *own* small
    distribution. ``add`` admits a tenant and returns its stable pool
    :class:`~repro.pool.Handle`; ``sample`` resolves one QMC draw per slot
    against that slot's distribution through the **stream-aware drain**: the
    slot streams live device-side (:class:`DeviceQmcStreams`), one jitted
    pre-pass ranks duplicate slots and advances every counter, and each
    touched size class resolves its lanes with a single coalesced
    ``forest_sample_batched_streams`` launch that computes the QMC points
    in-kernel — no host-side uniform generation or counter bookkeeping on
    the hot path. ``device_streams=False`` falls back to the host
    :class:`QmcStreams` oracle path (bit-equal draws; the differential
    suite pins it). ``update``/``remove`` re-target and retire tenants in
    place; slot QMC streams keep their counters across tenant churn, so
    stratification survives distribution swaps exactly as in
    :class:`ForestSampler`.

    **Stream kind and per-tenant method.** ``streams="qmc"`` (default)
    drives the per-slot low-discrepancy streams above; ``streams="prng"``
    replaces them with one seeded PRNG (the MC baseline — no
    stratification to protect). Tenants declare stream sensitivity at
    admission: ``method="forest"`` (monotone map, QMC-safe),
    ``method="alias"`` (packed O(1) tables — the bulk fast path), or
    ``method="auto"`` (default), which picks **alias under PRNG streams
    and forest under QMC streams** — exactly the paper's tradeoff: spend
    the descent only where a stratified stream would be destroyed by the
    non-monotone alias map."""

    def __init__(self, n_slots: int = 64, seed: int = 0, min_class: int = 8,
                 m: int | None = None, use_pallas: bool = True,
                 device_streams: bool = True, streams: str = "qmc",
                 policy: str = "reject"):
        from repro.pool import ForestPool  # lazy: serve stays importable

        if streams not in ("qmc", "prng"):
            raise ValueError(f"streams must be 'qmc' or 'prng', got {streams!r}")
        self.pool = ForestPool(min_class=min_class, m=m, policy=policy)
        self.stream_kind = streams
        self.device_streams = device_streams and streams == "qmc"
        if streams == "qmc":
            self.streams = (
                DeviceQmcStreams(n_slots, seed) if device_streams
                else QmcStreams(n_slots, seed)
            )
            self.rng = None
        else:
            self.streams = None
            self.rng = np.random.default_rng(seed)
        self.use_pallas = use_pallas

    def _resolve(self, method: str) -> str:
        """``auto`` -> alias for PRNG streams (nothing to protect, take the
        O(1) path), forest for QMC streams (the monotone map keeps the
        stratification the streams exist for)."""
        if method == "auto":
            return "alias" if self.stream_kind == "prng" else "forest"
        return method

    def add(self, weights, method: str = "auto"):
        """Admit one tenant; returns its pool handle. ``method`` is
        ``"forest"``/``"alias"``/``"auto"`` (see the class docstring)."""
        return self.pool.insert(weights, method=self._resolve(method))

    def add_many(self, weights_list, method="auto"):
        """Admit an admission wave through the fused batched builders.
        ``method`` is one choice for the wave or a per-tenant sequence."""
        if isinstance(method, str):
            methods = [self._resolve(method)] * len(weights_list)
        else:
            methods = [self._resolve(m) for m in method]
        return self.pool.insert_many(weights_list, method=methods)

    def update(self, handle, weights=None, *, delta=None) -> None:
        self.pool.update_weights(handle, weights, delta=delta)

    def remove(self, handle) -> None:
        self.pool.evict(handle)

    def sample(self, handles, slots: np.ndarray) -> np.ndarray:
        """One draw per slot from that slot's tenant distribution — the
        batched drain. ``handles[i]`` pairs with ``slots[i]``'s stream.
        Under QMC streams this is one pool call regardless of tenant
        methods (forest groups walk the stream-aware descent, alias groups
        consume the same pre-pass points); under PRNG streams the uniforms
        are one seeded vector draw."""
        if self.stream_kind == "prng":
            xi = self.rng.random(len(slots)).astype(np.float32)
            return self.pool.sample(handles, xi, use_pallas=self.use_pallas)
        if self.device_streams:
            return self.pool.sample_streams(
                handles, np.asarray(slots), self.streams,
                use_pallas=self.use_pallas,
            )
        xi = self.streams.next(np.asarray(slots))
        return self.pool.sample(handles, xi, use_pallas=self.use_pallas)

    def snapshot(self) -> dict:
        """Pool arenas + exact stream/PRNG state — everything a resumed
        process needs for bit-identical subsequent drains."""
        return dict(
            kind="pooled_forest_sampler",
            pool=self.pool.snapshot(),
            stream_kind=self.stream_kind,
            device_streams=self.device_streams,
            streams=None if self.streams is None else self.streams.snapshot(),
            rng=_rng_state(self.rng),
            use_pallas=self.use_pallas,
        )

    @classmethod
    def restore(cls, state: dict) -> "PooledForestSampler":
        from repro.pool import ForestPool  # lazy: serve stays importable

        if state.get("kind") != "pooled_forest_sampler":
            raise ValueError(
                f"not a PooledForestSampler snapshot: {state.get('kind')!r}"
            )
        s = cls(n_slots=1, streams=state["stream_kind"],
                device_streams=state["device_streams"],
                use_pallas=state["use_pallas"])
        s.pool = ForestPool.restore(state["pool"])
        s.streams = restore_streams(state["streams"])
        s.rng = _rng_restore(state["rng"])
        return s


class TokenSampler:
    def __init__(self, mode: str = "inverse_qmc", n_slots: int = 64,
                 temperature: float = 1.0, seed: int = 0, use_pallas: bool = True):
        assert mode in ("inverse_qmc", "inverse_rng", "alias")
        self.mode = mode
        self.temperature = temperature
        self.streams = QmcStreams(n_slots, seed)
        self.rng = np.random.default_rng(seed)
        self.use_pallas = use_pallas

    def uniforms(self, slots: np.ndarray) -> np.ndarray:
        if self.mode == "inverse_qmc":
            return self.streams.next(slots)
        return self.rng.random(len(slots)).astype(np.float32)

    def sample(self, logits: jax.Array, slots: np.ndarray) -> np.ndarray:
        """logits (B, V) -> token ids (B,)."""
        if self.mode == "alias":
            p = np.asarray(jax.nn.softmax(logits / self.temperature, axis=-1))
            # every mode consumes the SAME per-slot draw protocol: mode
            # comparisons (inverse_rng vs alias) then contrast mappings,
            # not randomness, and the serving-diversity bench is honest
            xi = self.uniforms(slots)
            out = np.empty(len(slots), np.int64)
            for i in range(len(slots)):  # serial build per row — the point
                t = build_alias(p[i])
                out[i] = int(np.asarray(sample_alias(t, jnp.float32(xi[i]))))
            return out.astype(np.int32)
        xi = self.uniforms(slots)
        cdf = ops.fused_cdf(
            logits / self.temperature, softmax=True, use_pallas=self.use_pallas
        )
        idx = ops.sample_rows(cdf, jnp.asarray(xi)[:, None], use_pallas=self.use_pallas)
        return np.asarray(idx)[:, 0]

    def snapshot(self) -> dict:
        return dict(
            kind="token_sampler", mode=self.mode,
            temperature=self.temperature, use_pallas=self.use_pallas,
            streams=self.streams.snapshot(), rng=_rng_state(self.rng),
        )

    @classmethod
    def restore(cls, state: dict) -> "TokenSampler":
        if state.get("kind") != "token_sampler":
            raise ValueError(f"not a TokenSampler snapshot: {state.get('kind')!r}")
        s = cls(mode=state["mode"], n_slots=1,
                temperature=state["temperature"],
                use_pallas=state["use_pallas"])
        s.streams = restore_streams(state["streams"])
        s.rng = _rng_restore(state["rng"])
        return s
