"""Token samplers for serving: the paper's monotone inversion vs the Alias
Method, with per-slot QMC uniform streams.

Modes:
  * ``inverse_qmc``  — fused softmax->CDF + tiled inverse (kernels), uniforms
    from per-slot scrambled van-der-Corput streams. Monotone warp => the
    stream's stratification survives (paper Sec. 3); best-of-n decode from
    one distribution provably covers the distribution better (benchmark
    ``benchmarks/serving_diversity.py``).
  * ``inverse_rng``  — same mapping, PRNG uniforms (the MC baseline).
  * ``alias``        — Walker/Vose per-row alias tables (serial build, non-
    monotone mapping; the paper's antagonist, kept for comparison).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_forest, sample_forest
from repro.core.alias import build_alias, sample_alias
from repro.core.cdf import normalize_weights, updated_weights
from repro.core.lds import radical_inverse_base2
from repro.kernels import ops


class QmcStreams:
    """Per-slot low-discrepancy uniform streams with Cranley-Patterson
    rotations (slot-hash offsets keep slots decorrelated but stratified)."""

    def __init__(self, n_slots: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.offsets = rng.random(n_slots).astype(np.float32)
        self.counters = np.zeros(n_slots, np.uint32)

    def next(self, slots: np.ndarray | None = None) -> np.ndarray:
        """One stream point per requested slot occurrence. A slot repeated k
        times in one drain draws its next k *distinct* stream points (the
        j-th occurrence, in call order, advances to counter+j) and its
        counter advances by k — fancy-index ``counters[slots] += 1`` would
        collapse duplicate increments and hand every occurrence the same
        point (identical best-of-n candidates)."""
        if slots is None:
            slots = np.arange(len(self.offsets))
        slots = np.asarray(slots)
        order = np.argsort(slots, kind="stable")
        sorted_slots = slots[order]
        first = np.searchsorted(sorted_slots, sorted_slots, side="left")
        rank = np.empty(len(slots), np.uint32)
        rank[order] = (np.arange(len(slots)) - first).astype(np.uint32)
        xi = (
            radical_inverse_base2(self.counters[slots] + rank)
            + self.offsets[slots]
        ) % 1.0
        np.add.at(self.counters, slots, 1)
        return xi.astype(np.float32)


class ForestSampler:
    """Shared-distribution serving sampler: ONE static distribution (draft
    prior, data mixture, env-map row), many draws per step — the paper's
    amortized workload behind a serving-shaped API.

    Builds the radix forest once at construction; every ``sample`` call
    inverts the CDF at the slots' QMC streams (monotone warp, so the
    stratification survives). ``sharded=True`` opts into the cell-partitioned
    :mod:`repro.dist.forest` path: guide cells are partitioned over the mesh
    data axis (``rebalance=True`` balances the partition by leaf occupancy
    for spiky priors) and each draw is resolved by its owning shard
    (bit-identical to the single-device path — the dist conformance suite
    gates that). :meth:`update_weights` swaps the distribution in place —
    the sharded path rebuilds only the shards whose windows changed, and the
    per-slot QMC streams continue uninterrupted."""

    def __init__(self, weights, m: int | None = None, sharded: bool = False,
                 mesh=None, n_slots: int = 64, seed: int = 0,
                 rebalance: bool = False, routed: bool = True):
        self._raw = np.asarray(weights, np.float64)
        w = normalize_weights(self._raw)
        m = m or max(len(w), 16)
        self.sharded = sharded
        # Owner-routed all-to-all bulk drain (default) vs the replicated
        # masked-psum oracle — identical draws; routed is the scaling path.
        self.routed = routed
        self.streams = QmcStreams(n_slots, seed)
        if sharded:
            from repro.dist import forest as DF  # lazy: serve stays importable

            self.forest, self.mesh = DF.build_forest_sharded_auto(
                jnp.asarray(w), m, mesh=mesh, rebalance=rebalance
            )
        else:
            self.mesh = None
            self.forest = build_forest(jnp.asarray(w), m)

    def update_weights(self, weights=None, *, delta=None) -> None:
        """In-place distribution update (new full weights, or a delta added
        to the current raw weights). Slot streams keep their counters, so a
        long-lived serving loop re-targets without a stratification reset."""
        self._raw, w = updated_weights(self._raw, weights, delta=delta)
        if self.sharded:
            from repro.dist import forest as DF

            self.forest = DF.update_forest_sharded(
                self.forest, jnp.asarray(w), mesh=self.mesh
            )
        else:
            self.forest = build_forest(jnp.asarray(w), self.forest.m)

    def sample(self, slots: np.ndarray) -> np.ndarray:
        xi = jnp.asarray(self.streams.next(slots))
        if self.sharded:
            from repro.dist import forest as DF

            return np.asarray(DF.sample_sharded(
                self.forest, xi, mesh=self.mesh, routed=self.routed
            ))
        return np.asarray(sample_forest(self.forest, xi))


class PooledForestSampler:
    """Multi-tenant serving sampler: thousands of per-request categoricals
    (draft priors, per-client mixtures, per-cell densities) in ONE
    :class:`repro.pool.ForestPool`, drained in bulk.

    The serving-shaped complement of :class:`ForestSampler` (one shared
    distribution, many draws): here every request owns its *own* small
    distribution. ``add`` admits a tenant and returns its stable pool
    :class:`~repro.pool.Handle`; ``sample`` resolves one QMC draw per slot
    against that slot's distribution with one batched kernel launch per
    touched size class (the batched drain), instead of a launch per tenant.
    ``update``/``remove`` re-target and retire tenants in place; slot QMC
    streams keep their counters across tenant churn, so stratification
    survives distribution swaps exactly as in :class:`ForestSampler`."""

    def __init__(self, n_slots: int = 64, seed: int = 0, min_class: int = 8,
                 m: int | None = None, use_pallas: bool = True):
        from repro.pool import ForestPool  # lazy: serve stays importable

        self.pool = ForestPool(min_class=min_class, m=m)
        self.streams = QmcStreams(n_slots, seed)
        self.use_pallas = use_pallas

    def add(self, weights):
        """Admit one tenant; returns its pool handle."""
        return self.pool.insert(weights)

    def add_many(self, weights_list):
        """Admit an admission wave through the fused batched builder."""
        return self.pool.insert_many(weights_list)

    def update(self, handle, weights=None, *, delta=None) -> None:
        self.pool.update_weights(handle, weights, delta=delta)

    def remove(self, handle) -> None:
        self.pool.evict(handle)

    def sample(self, handles, slots: np.ndarray) -> np.ndarray:
        """One draw per slot from that slot's tenant distribution — the
        batched drain. ``handles[i]`` pairs with ``slots[i]``'s QMC
        stream."""
        xi = self.streams.next(np.asarray(slots))
        return self.pool.sample(handles, xi, use_pallas=self.use_pallas)


class TokenSampler:
    def __init__(self, mode: str = "inverse_qmc", n_slots: int = 64,
                 temperature: float = 1.0, seed: int = 0, use_pallas: bool = True):
        assert mode in ("inverse_qmc", "inverse_rng", "alias")
        self.mode = mode
        self.temperature = temperature
        self.streams = QmcStreams(n_slots, seed)
        self.rng = np.random.default_rng(seed)
        self.use_pallas = use_pallas

    def uniforms(self, slots: np.ndarray) -> np.ndarray:
        if self.mode == "inverse_qmc":
            return self.streams.next(slots)
        return self.rng.random(len(slots)).astype(np.float32)

    def sample(self, logits: jax.Array, slots: np.ndarray) -> np.ndarray:
        """logits (B, V) -> token ids (B,)."""
        if self.mode == "alias":
            p = np.asarray(jax.nn.softmax(logits / self.temperature, axis=-1))
            out = np.empty(len(slots), np.int64)
            for i in range(len(slots)):  # serial build per row — the point
                t = build_alias(p[i])
                xi = self.rng.random()
                out[i] = int(np.asarray(sample_alias(t, jnp.float32(xi))))
            return out.astype(np.int32)
        xi = self.uniforms(slots)
        cdf = ops.fused_cdf(
            logits / self.temperature, softmax=True, use_pallas=self.use_pallas
        )
        idx = ops.sample_rows(cdf, jnp.asarray(xi)[:, None], use_pallas=self.use_pallas)
        return np.asarray(idx)[:, 0]
