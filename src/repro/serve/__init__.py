from .engine import Request, ServeEngine
from .sampler import (
    ForestSampler,
    PooledForestSampler,
    QmcStreams,
    TokenSampler,
)

__all__ = [
    "Request",
    "ServeEngine",
    "ForestSampler",
    "PooledForestSampler",
    "QmcStreams",
    "TokenSampler",
]
