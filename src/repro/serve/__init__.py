from .engine import Request, ServeEngine
from .sampler import (
    DeviceQmc2Streams,
    DeviceQmcStreams,
    ForestSampler,
    PooledForestSampler,
    Qmc2Streams,
    QmcStreams,
    SpatialSampler,
    TokenSampler,
)

__all__ = [
    "Request",
    "ServeEngine",
    "DeviceQmc2Streams",
    "DeviceQmcStreams",
    "ForestSampler",
    "PooledForestSampler",
    "Qmc2Streams",
    "QmcStreams",
    "SpatialSampler",
    "TokenSampler",
]
