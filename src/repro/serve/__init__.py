from .engine import Request, ServeEngine
from .sampler import QmcStreams, TokenSampler

__all__ = ["Request", "ServeEngine", "QmcStreams", "TokenSampler"]
