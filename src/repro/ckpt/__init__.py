from .checkpoint import (
    CheckpointManager,
    latest_step,
    load_state,
    restore,
    save,
    save_state,
)

__all__ = [
    "CheckpointManager",
    "latest_step",
    "load_state",
    "restore",
    "save",
    "save_state",
]
