"""Mesh-agnostic sharded checkpointing with atomic commit and auto-resume.

Fault-tolerance contract (tested in tests/test_fault_tolerance.py):
  * save(): write to ``step_N.tmp/``, fsync, atomic rename to ``step_N/`` —
    a crash mid-save never corrupts the latest checkpoint.
  * arrays are stored as full logical tensors (npy) + a JSON manifest of
    tree structure and dtypes. Restore re-shards onto ANY mesh/policy via
    jax.device_put with the target sharding (elastic scaling: a run saved on
    (16,16) restores onto (2,16,16) or a single CPU).
  * keep-last-k garbage collection; ``latest_step`` scans for auto-resume.
  * on real multi-host pods, gathering to host is replaced by per-shard
    writes (jax.experimental.array_serialization); the manifest format is
    unchanged — single-process here, so np.asarray(x) is the gather.

Async: ``CheckpointManager(async_save=True)`` snapshots to host then writes
on a worker thread, overlapping I/O with the next training step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        items.append((key, leaf))
    return items, treedef


def save(path: str | os.PathLike, tree: Any, step: int) -> Path:
    """Atomic checkpoint write; returns the committed directory."""
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    items, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(leaf)  # device->host gather (full logical array)
        fn = f"leaf_{i:05d}.npy"
        np.save(tmp / fn, arr)
        manifest["leaves"].append({"key": key, "file": fn, "dtype": str(arr.dtype)})
    (tmp / _MANIFEST).write_text(json.dumps(manifest))
    # fsync directory entries, then atomic publish
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str | os.PathLike) -> int | None:
    root = Path(path)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / _MANIFEST).exists()
    ]
    return max(steps) if steps else None


def restore(path: str | os.PathLike, like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; re-shard via ``shardings``
    (a matching tree of NamedShardings) for elastic mesh changes."""
    root = Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    items, treedef = _flatten(like)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    shard_items = None
    if shardings is not None:
        shard_items, _ = _flatten(shardings)
    leaves = []
    for i, (key, leaf) in enumerate(items):
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(d / meta["file"])
        expect = getattr(leaf, "shape", None)
        if expect is not None and tuple(arr.shape) != tuple(expect):
            raise ValueError(f"{key}: shape {arr.shape} != expected {expect}")
        if shard_items is not None:
            arr = jax.device_put(arr, shard_items[i][1])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


# ---------------------------------------------------------------- state blobs
#
# ``save``/``restore`` speak jax pytrees of arrays — the trainer's language.
# Serving state (``ForestPool.snapshot()`` and friends) is richer: nested
# dicts with int keys, free *lists* whose order matters, sets, strings,
# None, and numpy arrays. ``save_state``/``load_state`` give that shape the
# same atomic-commit durability: containers are encoded as tagged JSON
# (``__dict__`` keeps int keys and insertion order, ``__tuple__``/``__set__``
# round-trip exactly), arrays spill to npy leaves next to the manifest.

_STATE = "state.json"


def _enc_state(x: Any, arrays: list[np.ndarray]) -> Any:
    if x is None or isinstance(x, (bool, str)):
        return x
    if isinstance(x, (int, np.integer)):
        return int(x)
    if isinstance(x, (float, np.floating)):
        return float(x)
    if isinstance(x, (np.ndarray, jax.Array)):
        arrays.append(np.asarray(x))
        return {"__arr__": len(arrays) - 1}
    if isinstance(x, tuple):
        return {"__tuple__": [_enc_state(v, arrays) for v in x]}
    if isinstance(x, list):
        return {"__list__": [_enc_state(v, arrays) for v in x]}
    if isinstance(x, (set, frozenset)):
        enc = [_enc_state(v, arrays) for v in x]
        return {"__set__": sorted(enc, key=repr)}  # deterministic bytes
    if isinstance(x, dict):
        return {
            "__dict__": [
                [_enc_state(k, arrays), _enc_state(v, arrays)]
                for k, v in x.items()
            ]
        }
    raise TypeError(f"save_state cannot encode {type(x).__name__}")


def _dec_state(x: Any, arrays: list[np.ndarray]) -> Any:
    if isinstance(x, dict):
        if "__arr__" in x:
            return arrays[x["__arr__"]]
        if "__tuple__" in x:
            return tuple(_dec_state(v, arrays) for v in x["__tuple__"])
        if "__list__" in x:
            return [_dec_state(v, arrays) for v in x["__list__"]]
        if "__set__" in x:
            return {_dec_state(v, arrays) for v in x["__set__"]}
        if "__dict__" in x:
            return {
                _dec_state(k, arrays): _dec_state(v, arrays)
                for k, v in x["__dict__"]
            }
        raise ValueError(f"unknown state tag {sorted(x)!r}")
    return x


def save_state(path: str | os.PathLike, state: Any, step: int) -> Path:
    """Atomically commit a nested python state blob (same tmp/fsync/rename
    contract as :func:`save`; interoperates with :func:`latest_step`)."""
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    arrays: list[np.ndarray] = []
    enc = _enc_state(state, arrays)
    files = []
    for i, arr in enumerate(arrays):
        fn = f"leaf_{i:05d}.npy"
        np.save(tmp / fn, arr)
        files.append({"file": fn, "dtype": str(arr.dtype)})
    (tmp / _STATE).write_text(json.dumps(enc))
    (tmp / _MANIFEST).write_text(
        json.dumps({"step": step, "kind": "state", "leaves": files})
    )
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_state(path: str | os.PathLike, step: int | None = None) -> tuple[Any, int]:
    """Load a :func:`save_state` blob (latest step by default)."""
    root = Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no state snapshot under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    if manifest.get("kind") != "state":
        raise ValueError(f"{d} is a pytree checkpoint, not a state blob")
    arrays = [np.load(d / m["file"]) for m in manifest["leaves"]]
    enc = json.loads((d / _STATE).read_text())
    return _dec_state(enc, arrays), step


class CheckpointManager:
    """keep-last-k, optional async, auto-resume.

    Async worker failures are never swallowed: an exception on the write
    thread is captured and re-raised on the next :meth:`save` or
    :meth:`wait` call — a training loop cannot keep running for hours on
    the belief that checkpoints exist when the disk filled up at step 100.
    """

    def __init__(self, path: str | os.PathLike, keep: int = 3,
                 async_save: bool = False):
        self.root = Path(path)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._exc: BaseException | None = None

    def _gc(self) -> None:
        steps = sorted(
            p for p in self.root.iterdir()
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        )
        for p in steps[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def _raise_pending(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise RuntimeError("async checkpoint save failed") from exc

    def save(self, tree: Any, step: int) -> None:
        if self._thread is not None:
            self._thread.join()  # one in flight
            self._thread = None
        self._raise_pending()
        if self.async_save:
            host = jax.tree.map(np.asarray, tree)  # snapshot now

            def work():
                try:
                    save(self.root, host, step)
                    self._gc()
                except BaseException as e:  # surfaced on next save()/wait()
                    self._exc = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save(self.root, tree, step)
            self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def restore_latest(self, like: Any, shardings: Any = None):
        return restore(self.root, like, None, shardings)
