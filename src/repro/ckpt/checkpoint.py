"""Mesh-agnostic sharded checkpointing with atomic commit and auto-resume.

Fault-tolerance contract (tested in tests/test_fault_tolerance.py):
  * save(): write to ``step_N.tmp/``, fsync, atomic rename to ``step_N/`` —
    a crash mid-save never corrupts the latest checkpoint.
  * arrays are stored as full logical tensors (npy) + a JSON manifest of
    tree structure and dtypes. Restore re-shards onto ANY mesh/policy via
    jax.device_put with the target sharding (elastic scaling: a run saved on
    (16,16) restores onto (2,16,16) or a single CPU).
  * keep-last-k garbage collection; ``latest_step`` scans for auto-resume.
  * on real multi-host pods, gathering to host is replaced by per-shard
    writes (jax.experimental.array_serialization); the manifest format is
    unchanged — single-process here, so np.asarray(x) is the gather.

Async: ``CheckpointManager(async_save=True)`` snapshots to host then writes
on a worker thread, overlapping I/O with the next training step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree: Any) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        items.append((key, leaf))
    return items, treedef


def save(path: str | os.PathLike, tree: Any, step: int) -> Path:
    """Atomic checkpoint write; returns the committed directory."""
    root = Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    items, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(items):
        arr = np.asarray(leaf)  # device->host gather (full logical array)
        fn = f"leaf_{i:05d}.npy"
        np.save(tmp / fn, arr)
        manifest["leaves"].append({"key": key, "file": fn, "dtype": str(arr.dtype)})
    (tmp / _MANIFEST).write_text(json.dumps(manifest))
    # fsync directory entries, then atomic publish
    for f in tmp.iterdir():
        fd = os.open(f, os.O_RDONLY)
        os.fsync(fd)
        os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str | os.PathLike) -> int | None:
    root = Path(path)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        and (p / _MANIFEST).exists()
    ]
    return max(steps) if steps else None


def restore(path: str | os.PathLike, like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; re-shard via ``shardings``
    (a matching tree of NamedShardings) for elastic mesh changes."""
    root = Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    items, treedef = _flatten(like)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    shard_items = None
    if shardings is not None:
        shard_items, _ = _flatten(shardings)
    leaves = []
    for i, (key, leaf) in enumerate(items):
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(d / meta["file"])
        expect = getattr(leaf, "shape", None)
        if expect is not None and tuple(arr.shape) != tuple(expect):
            raise ValueError(f"{key}: shape {arr.shape} != expected {expect}")
        if shard_items is not None:
            arr = jax.device_put(arr, shard_items[i][1])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """keep-last-k, optional async, auto-resume."""

    def __init__(self, path: str | os.PathLike, keep: int = 3,
                 async_save: bool = False):
        self.root = Path(path)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def _gc(self) -> None:
        steps = sorted(
            p for p in self.root.iterdir()
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        )
        for p in steps[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)

    def save(self, tree: Any, step: int) -> None:
        if self._thread is not None:
            self._thread.join()  # one in flight
        if self.async_save:
            host = jax.tree.map(np.asarray, tree)  # snapshot now

            def work():
                save(self.root, host, step)
                self._gc()

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            save(self.root, tree, step)
            self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like: Any, shardings: Any = None):
        return restore(self.root, like, None, shardings)
