"""Deterministic synthetic data pipeline (restart-safe by construction).

Each corpus is a Markov-ish token source with its own Zipf exponent and a
corpus-specific bigram shift, so models *can* learn (loss decreases) and the
mixture identity of a sequence is statistically visible. Batches are pure
functions of (seed, step) — resuming at step k reproduces the exact stream,
which the fault-tolerance test asserts bitwise.
"""
from __future__ import annotations

import numpy as np

from .mixture import MixtureSampler


class SyntheticCorpus:
    """Zipf unigrams + deterministic bigram drift, per corpus id."""

    def __init__(self, vocab: int, corpus_id: int, zipf: float | None = None):
        self.vocab = vocab
        self.corpus_id = corpus_id
        self.zipf = zipf if zipf is not None else 1.1 + 0.25 * (corpus_id % 4)

    def sample(self, rng: np.random.Generator, n: int, seq: int) -> np.ndarray:
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = ranks ** (-self.zipf)
        p /= p.sum()
        base = rng.choice(self.vocab, size=(n, seq), p=p)
        # bigram structure: token_t depends weakly on token_{t-1}
        shift = (self.corpus_id * 97 + 13) % self.vocab
        drift = (np.cumsum(base, axis=1) + shift) % self.vocab
        mix = rng.random((n, seq)) < 0.3
        return np.where(mix, drift, base).astype(np.int32)


def make_batch(
    cfg,
    step: int,
    global_batch: int,
    seq_len: int,
    mixture: MixtureSampler | None = None,
    seed: int = 0,
) -> dict[str, np.ndarray]:
    """Pure function of (cfg, step, seed): the restart-safety contract."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    batch: dict[str, np.ndarray] = {}
    if mixture is not None:
        corpus_ids = mixture.sample(step, global_batch)
    else:
        corpus_ids = np.zeros(global_batch, np.int64)
    toks = np.zeros((global_batch, seq_len), np.int32)
    for cid in np.unique(corpus_ids):
        rows = np.where(corpus_ids == cid)[0]
        toks[rows] = SyntheticCorpus(cfg.vocab, int(cid)).sample(
            rng, len(rows), seq_len
        )
    if cfg.frontend == "embed":
        emb = rng.normal(0, 1, (global_batch, seq_len, cfg.d_model))
        batch["embeds"] = emb.astype(np.float32)
    else:
        batch["tokens"] = toks
    if cfg.encoder_layers:
        batch["frames"] = rng.normal(
            0, 1, (global_batch, seq_len, cfg.d_model)
        ).astype(np.float32)
    batch["labels"] = toks
    return batch
