"""Data-mixture sampling via the radix tree forest — the paper's amortized
workload: ONE static distribution (corpus weights), millions of draws.

Build once (massively parallel, Sec. 3.2), then every training batch draws
its per-sequence corpus assignment by inverting the mixture CDF at a
low-discrepancy stream. The monotone mapping means the LDS stratification
survives the warp (paper Sec. 1): corpus proportions per batch track the
target weights with O(1/N) discrepancy instead of O(1/sqrt(N)) MC noise —
``tests/test_data_pipeline.py::test_qmc_mixture_is_lower_variance``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import build_forest, sample_forest
from repro.core.cdf import normalize_weights, updated_weights
from repro.core.lds import radical_inverse_base2


class MixtureSampler:
    def __init__(self, weights, m: int | None = None, seed: int = 0,
                 sharded: bool = False, mesh=None, rebalance: bool = False,
                 routed: bool = True):
        self._raw = np.asarray(weights, np.float64)
        w = normalize_weights(self._raw)
        self.weights = w
        m = m or max(len(w), 16)
        self.sharded = sharded
        # Owner-routed all-to-all bulk drain (default) vs the replicated
        # masked-psum oracle — identical draws; routed is the scaling path.
        self.routed = routed
        if sharded:
            # Opt-in cell-partitioned build/sampling over the mesh data axis
            # (bit-identical to the single-device path; repro.dist.forest).
            from repro.dist import forest as DF

            self.forest, self.mesh = DF.build_forest_sharded_auto(
                jnp.asarray(w), m, mesh=mesh, rebalance=rebalance
            )
        else:
            self.mesh = None
            self.forest = build_forest(jnp.asarray(w), m)
        # Cranley-Patterson rotation so different runs decorrelate while
        # keeping the sequence's low discrepancy.
        self.offset = np.float32(np.random.default_rng(seed).random())

    def update_weights(self, weights=None, *, delta=None) -> None:
        """Re-target the mixture in place (curriculum shifts, corpus swaps):
        new full weights, or a delta added to the current raw weights. The
        sharded path rebuilds only the shards whose leaf windows changed;
        ``sample`` stays deterministic in (step, n) against the new target."""
        self._raw, self.weights = updated_weights(self._raw, weights,
                                                  delta=delta)
        if self.sharded:
            from repro.dist import forest as DF

            self.forest = DF.update_forest_sharded(
                self.forest, jnp.asarray(self.weights), mesh=self.mesh
            )
        else:
            self.forest = build_forest(jnp.asarray(self.weights), self.forest.m)

    def sample(self, step: int, n: int, qmc: bool = True) -> np.ndarray:
        """Corpus index for each of n sequences of global batch ``step``.
        Deterministic in (step, n): restart-safe."""
        start = np.uint32(step * n)
        idx = np.arange(n, dtype=np.uint32) + start
        if qmc:
            xi = (radical_inverse_base2(idx) + self.offset) % 1.0
        else:
            xi = np.random.default_rng(step).random(n)
        xi = np.asarray(xi, np.float32)
        if self.sharded:
            from repro.dist import forest as DF

            return np.asarray(DF.sample_sharded(
                self.forest, jnp.asarray(xi), mesh=self.mesh, routed=self.routed
            ))
        return np.asarray(sample_forest(self.forest, jnp.asarray(xi)))
