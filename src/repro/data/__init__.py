from .mixture import MixtureSampler
from .pipeline import SyntheticCorpus, make_batch

__all__ = ["MixtureSampler", "SyntheticCorpus", "make_batch"]
