"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bits import DIST_SENTINEL


def ref_cdf_scan(x: jax.Array, softmax: bool = True) -> jax.Array:
    """Oracle for kernels.cdf_scan.cdf_scan (float32 accumulation)."""
    x = x.astype(jnp.float32)
    if softmax:
        x = x - jnp.max(x, axis=-1, keepdims=True)
        e = jnp.exp(x)
    else:
        e = x
    c = jnp.cumsum(e, axis=-1)
    return c / c[..., -1:]


def ref_sample_rows(cdf_rows: jax.Array, xi: jax.Array) -> jax.Array:
    """Oracle for kernels.sample_tiled.sample_rows."""
    V = cdf_rows.shape[-1]

    def one(row, u):
        return jnp.clip(
            jnp.searchsorted(row, u, side="right").astype(jnp.int32), 0, V - 1
        )

    return jax.vmap(one)(cdf_rows, xi)


def ref_forest_sample(
    cdf, table, left, right, xi, cell_first=None, fallback=None, depth: int = 64
) -> jax.Array:
    """Oracle for kernels.forest_sample.forest_sample (same optional
    degenerate-cell pre-resolution as the kernel)."""
    n = left.shape[0]
    m = table.shape[0]
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    j = table[g]

    if cell_first is not None and fallback is not None:
        # Same pre-resolution as core.sample.sample_forest — literally the
        # same bisection, so elementwise agreement is structural.
        from repro.core.sample import _bisect

        flagged = fallback[g] & (j >= 0)
        bal = _bisect(cdf, xi, cell_first[g], cell_first[g + 1], 32)
        j = jnp.where(flagged, ~bal, j)

    def body(_, j):
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < cdf[jj]
        nxt = jnp.where(go_left, left[jj], right[jj])
        return jnp.where(j >= 0, nxt, j)

    return ~jax.lax.fori_loop(0, depth, body, j)


def ref_forest_sample_batched(
    cdf, table, left, right, dist_id, xi, cell_first=None, fallback=None,
    depth: int = 64,
) -> jax.Array:
    """Oracle for kernels.forest_sample.forest_sample_batched: lane q
    descends distribution dist_id[q]'s row with 2-D gathers (same optional
    degenerate-cell pre-resolution as the kernel). Sentinel lanes
    (``dist_id < 0``) resolve to 0 without descending — same contract as
    the kernel, so padded drains stay elementwise comparable."""
    B, m = table.shape
    n = left.shape[1]
    raw = dist_id.astype(jnp.int32)
    valid = raw >= 0
    did = jnp.clip(raw, 0, B - 1)
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    j = jnp.where(valid, table[did, g], -1)  # sentinel lanes sit at leaf ~0

    if cell_first is not None and fallback is not None:
        flagged = fallback[did, g] & (j >= 0)
        lo = cell_first[did, g]
        hi = cell_first[did, g + 1]

        def bisect_body(_, state):
            lo, hi = state
            mid = (lo + hi + 1) >> 1
            ge = xi >= cdf[did, mid]
            return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid - 1)

        lo, _ = jax.lax.fori_loop(0, 32, bisect_body, (lo, hi))
        j = jnp.where(flagged, ~lo, j)

    def body(_, j):
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < cdf[did, jj]
        nxt = jnp.where(go_left, left[did, jj], right[did, jj])
        return jnp.where(j >= 0, nxt, j)

    return ~jax.lax.fori_loop(0, depth, body, j)


def ref_forest_sample_batched_streams(
    cdf, table, left, right, dist_id, counter, offset_bits,
    cell_first=None, fallback=None, depth: int = 64,
):
    """Oracle for kernels.forest_sample.forest_sample_batched_streams: the
    same exact 24-bit fixed-point radical-inverse + rotation pipeline
    (``core.lds.qmc_point``), then the batched descent. Returns
    ``(idx, xi)`` exactly like the kernel."""
    from repro.core.lds import qmc_point

    xi = qmc_point(counter, offset_bits)
    idx = ref_forest_sample_batched(
        cdf, table, left, right, dist_id, xi, cell_first, fallback, depth
    )
    return idx, xi


def ref_alias_build_batched(weights: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.alias_build.alias_build_batched: literally the
    same positional split-and-pack row core (rows are independent, so the
    kernel's row blocking cannot change bits — agreement is structural)."""
    from repro.kernels.alias_build import alias_split_pack_rows

    return alias_split_pack_rows(jnp.asarray(weights, jnp.float32))


def ref_alias_sample_batched(
    q: jax.Array, alias: jax.Array, dist_id: jax.Array, xi: jax.Array
) -> jax.Array:
    """Oracle for kernels.alias_sample.alias_sample_batched: same float32
    arithmetic (scale, truncate, clamp into [0, 1), one comparison) with
    2-D gathers. Sentinel lanes (``dist_id < 0``) resolve to 0 without
    touching any row — same contract as the kernel."""
    from repro.core.alias import ALIAS_FRAC_MAX

    B, n = q.shape
    raw = dist_id.astype(jnp.int32)
    valid = raw >= 0
    did = jnp.clip(raw, 0, B - 1)
    scaled = xi * jnp.float32(n)
    cell = jnp.clip(scaled.astype(jnp.int32), 0, n - 1)
    frac = jnp.clip(
        scaled - cell.astype(jnp.float32), 0.0, jnp.float32(ALIAS_FRAC_MAX)
    )
    out = jnp.where(frac < q[did, cell], cell, alias[did, cell])
    return jnp.where(valid, out, 0).astype(jnp.int32)


def ref_forest_delta(data: jax.Array, m: int) -> jax.Array:
    """Oracle for kernels.forest_delta.forest_delta. Cells are clipped to
    [0, m-1] exactly like core.forest._cells, so the crossing mask is the
    tree builder's by construction, not by a rounding argument."""
    bits = jax.lax.bitcast_convert_type(data.astype(jnp.float32), jnp.uint32)
    raw = bits[:-1] ^ bits[1:]
    cells = jnp.clip(
        jnp.floor(data * jnp.float32(m)).astype(jnp.int32), 0, m - 1
    )
    return jnp.where(cells[:-1] != cells[1:], jnp.uint32(DIST_SENTINEL), raw)


def ref_forest_delta_update(data_old, data_new, m: int):
    """Oracle for kernels.forest_delta.forest_delta_update."""
    bits_old = jax.lax.bitcast_convert_type(data_old.astype(jnp.float32), jnp.uint32)
    bits_new = jax.lax.bitcast_convert_type(data_new.astype(jnp.float32), jnp.uint32)
    return ref_forest_delta(data_new, m), bits_old != bits_new


def ref_flash_attention(q, k, v, causal: bool = True) -> jax.Array:
    """Oracle for kernels.flash_attention (materialized scores)."""
    import numpy as np

    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgk,bthk->bhgqt", qg, k.astype(jnp.float32))
    s = s / np.sqrt(hd)
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqt,bthk->bqhgk", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
