"""Flash attention (online-softmax tiling) for the prefill hot path.

prefill_32k cells spend most of their compute term in S^2 attention; the
XLA default materializes (B, H, S, S) score tiles through HBM. This kernel
keeps the running (max, sum, acc) in VMEM scratch and streams K/V tiles, the
standard memory-hierarchy adaptation for TPU (HBM -> VMEM -> MXU):

  grid (B, H, Sq/Tq, Sk/Tk), innermost kv axis sequential; per (q-tile):
    m_new = max(m, rowmax(S_ij));  l = l*exp(m-m_new) + rowsum(P);
    acc = acc*exp(m-m_new) + P @ V_j;  out = acc / l at the last kv step.

Causal masking is per-element within the tile (iota comparison); GQA maps
query head h to kv head h // (H/KV) in the BlockSpec index map, so no
replication of K/V in memory. Validated against the pure-jnp oracle over
shape/dtype/causal/GQA sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, block_q: int, block_k: int,
                  kv_len: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (Tq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (Tk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = q @ k.T                                          # (Tq, Tk)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < kv_len                                # mask padded keys
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        valid = valid & (kpos <= qpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / float(np.sqrt(hd))
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    Sqp = (Sq + bq - 1) // bq * bq
    Skp = (Sk + bk - 1) // bk * bk
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)),
                 constant_values=0)
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    grid = (B, H, Sqp // bq, Skp // bk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal,
            block_q=bq, block_k=bk, kv_len=Sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :Sq].transpose(0, 2, 1, 3)
