"""Public jit'd kernel entry points with backend dispatch.

On TPU the Pallas kernels compile natively; everywhere else (this CPU
container, tests, dry-runs) they run in ``interpret=True`` mode, which
executes the same kernel bodies through XLA for bit-accurate validation.
`use_pallas=False` (the dry-run default) swaps in the pure-jnp references so
512-device compiles stay fast — standard backend-selection practice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.forest import RadixForest

from . import ref
from .alias_build import alias_build_batched as _alias_build_batched
from .alias_sample import alias_sample_batched as _alias_sample_batched
from .cdf_scan import cdf_scan as _cdf_scan
from .forest_delta import forest_delta as _forest_delta
from .forest_delta import forest_delta_update as _forest_delta_update
from .forest_sample import forest_sample as _forest_sample
from .forest_sample import forest_sample_batched as _forest_sample_batched
from .forest_sample import (
    forest_sample_batched_streams as _forest_sample_batched_streams,
)
from .sample_tiled import sample_rows as _sample_rows


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def use_pallas_default() -> bool:
    """The repo-wide dispatch policy: Pallas kernels compile natively on TPU;
    elsewhere the pure-jnp references are the same bits for a fraction of
    the interpret-mode dispatch cost. Single-sourced so the dist and pool
    layers cannot drift from each other."""
    return jax.default_backend() == "tpu"


def fused_cdf(x: jax.Array, softmax: bool = True, use_pallas: bool = True) -> jax.Array:
    """(B, V) logits/weights -> (B, V) inclusive CDF rows."""
    if not use_pallas:
        return ref.ref_cdf_scan(x, softmax=softmax)
    return _cdf_scan(x, softmax=softmax, interpret=_interpret())


def sample_rows(cdf_rows: jax.Array, xi: jax.Array, use_pallas: bool = True) -> jax.Array:
    """Per-row inverse CDF: (B, V) x (B, k) -> (B, k) int32 indices."""
    if not use_pallas:
        return ref.ref_sample_rows(cdf_rows, xi)
    return _sample_rows(cdf_rows, xi, interpret=_interpret())


def forest_sample(forest: RadixForest, xi: jax.Array, use_pallas: bool = True) -> jax.Array:
    """Shared-distribution Algorithm 2 over a batch of uniforms.

    When the build flagged degenerate (tied-weight) cells, both paths get the
    forest's ``cell_first``/``fallback`` side tables so those lanes
    pre-resolve by bisection instead of running past the fixed trip count.
    Well-conditioned forests (no flagged cell — the common case) skip the
    side tables and the 32-trip pre-resolution entirely; this boundary is
    not jitted, so the concrete-flag check costs one small reduction."""
    degenerate = bool(jax.device_get(forest.fallback.any()))
    cf = forest.cell_first if degenerate else None
    fb = forest.fallback if degenerate else None
    if not use_pallas:
        return ref.ref_forest_sample(
            forest.cdf, forest.table, forest.left, forest.right, xi, cf, fb
        )
    return _forest_sample(
        forest.cdf, forest.table, forest.left, forest.right, xi, cf, fb,
        interpret=_interpret(),
    )


def forest_sample_batched(
    forest, dist_id: jax.Array, xi: jax.Array, use_pallas: bool = True,
    degenerate: bool | None = None, coalesce: bool = True,
) -> jax.Array:
    """Mixed-batch Algorithm 2 over B stacked forests (one launch).

    ``forest`` is any object with the stacked ``BatchedForest`` fields
    (``repro.pool.batched.BatchedForest``; duck-typed here so the kernel
    layer never imports the pool layer). Same degenerate-cell policy as
    :func:`forest_sample`: side tables ride along only when some row
    actually flagged a cell. Callers that track flagged rows host-side
    (``ForestPool``) pass ``degenerate`` explicitly and spare the serving
    hot path a blocking device round-trip per drain. Lanes with
    ``dist_id < 0`` are sentinels (padding): resolved to 0 without walking
    any tree. ``coalesce`` toggles the kernel's bucketing pre-pass (stable
    sort by owning tree; elementwise identical either way — the jnp
    reference is order-invariant and ignores it)."""
    if degenerate is None:
        degenerate = bool(jax.device_get(forest.fallback.any()))
    cf = forest.cell_first if degenerate else None
    fb = forest.fallback if degenerate else None
    if not use_pallas:
        return ref.ref_forest_sample_batched(
            forest.cdf, forest.table, forest.left, forest.right,
            dist_id, xi, cf, fb,
        )
    return _forest_sample_batched(
        forest.cdf, forest.table, forest.left, forest.right, dist_id, xi,
        cf, fb, interpret=_interpret(), coalesce=coalesce,
    )


def forest_sample_batched_streams(
    forest, dist_id: jax.Array, counter: jax.Array, offset_bits: jax.Array,
    use_pallas: bool = True, degenerate: bool | None = None,
    coalesce: bool = True,
):
    """Stream-aware mixed-batch drain: QMC state in, ``(idx, xi)`` out.

    ``counter`` (Q,) uint32 carries each lane's rank-adjusted stream counter
    and ``offset_bits`` (Q,) uint32 its slot's 24-bit Cranley-Patterson
    rotation; the base-2 radical inverse + rotation run device-side (both
    paths use the exact integer pipeline of ``core.lds``), so a full pool
    drain needs no host-side uniform generation or counter bookkeeping.
    Same degenerate/sentinel/coalesce policy as
    :func:`forest_sample_batched`."""
    if degenerate is None:
        degenerate = bool(jax.device_get(forest.fallback.any()))
    cf = forest.cell_first if degenerate else None
    fb = forest.fallback if degenerate else None
    if not use_pallas:
        return ref.ref_forest_sample_batched_streams(
            forest.cdf, forest.table, forest.left, forest.right,
            dist_id, counter, offset_bits, cf, fb,
        )
    return _forest_sample_batched_streams(
        forest.cdf, forest.table, forest.left, forest.right, dist_id,
        counter, offset_bits, cf, fb, interpret=_interpret(),
        coalesce=coalesce,
    )


def alias_build_batched(
    weights: jax.Array, use_pallas: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Batched split-and-pack alias construction: (B, n) stacked weights ->
    packed ``(q, alias)`` (B, n) stacks, one fused program. Rows with mixed
    lights/heavies pack via the positional prefix formulation; exactly
    uniform rows come back as identity tables. Both paths run the same row
    core, so they are bit-identical by construction."""
    if not use_pallas:
        return ref.ref_alias_build_batched(weights)
    return _alias_build_batched(weights, interpret=_interpret())


def alias_sample_batched(
    table, dist_id: jax.Array, xi: jax.Array, use_pallas: bool = True,
    coalesce: bool = True,
) -> jax.Array:
    """Mixed-batch alias drain over B stacked tables (one launch).

    ``table`` is any object with stacked ``q`` (B, n) f32 / ``alias``
    (B, n) i32 fields (``repro.pool.batched.BatchedAlias``; duck-typed so
    the kernel layer never imports the pool layer). O(1) per lane — two
    gathers and a comparison — which is why PRNG tenants route here; the
    mapping is non-monotone, so QMC tenants must not. Lanes with
    ``dist_id < 0`` are sentinels (padding) resolved to 0; ``coalesce``
    toggles the stable sort-by-row bucketing pre-pass (elementwise
    identical either way)."""
    if not use_pallas:
        return ref.ref_alias_sample_batched(table.q, table.alias, dist_id, xi)
    return _alias_sample_batched(
        table.q, table.alias, dist_id, xi, interpret=_interpret(),
        coalesce=coalesce,
    )


def forest_delta(data: jax.Array, m: int, use_pallas: bool = True) -> jax.Array:
    """Separator distances for forest construction."""
    if not use_pallas:
        return ref.ref_forest_delta(data, m)
    return _forest_delta(data, m, interpret=_interpret())


def forest_delta_update(
    data_old: jax.Array, data_new: jax.Array, m: int, use_pallas: bool = True
):
    """New separator distances + changed-leaf-bits mask for a weight update."""
    if not use_pallas:
        return ref.ref_forest_delta_update(data_old, data_new, m)
    return _forest_delta_update(data_old, data_new, m, interpret=_interpret())
