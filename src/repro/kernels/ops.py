"""Public jit'd kernel entry points with backend dispatch.

On TPU the Pallas kernels compile natively; everywhere else (this CPU
container, tests, dry-runs) they run in ``interpret=True`` mode, which
executes the same kernel bodies through XLA for bit-accurate validation.
`use_pallas=False` (the dry-run default) swaps in the pure-jnp references so
512-device compiles stay fast — standard backend-selection practice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.forest import RadixForest

from . import ref
from .cdf_scan import cdf_scan as _cdf_scan
from .forest_delta import forest_delta as _forest_delta
from .forest_sample import forest_sample as _forest_sample
from .sample_tiled import sample_rows as _sample_rows


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_cdf(x: jax.Array, softmax: bool = True, use_pallas: bool = True) -> jax.Array:
    """(B, V) logits/weights -> (B, V) inclusive CDF rows."""
    if not use_pallas:
        return ref.ref_cdf_scan(x, softmax=softmax)
    return _cdf_scan(x, softmax=softmax, interpret=_interpret())


def sample_rows(cdf_rows: jax.Array, xi: jax.Array, use_pallas: bool = True) -> jax.Array:
    """Per-row inverse CDF: (B, V) x (B, k) -> (B, k) int32 indices."""
    if not use_pallas:
        return ref.ref_sample_rows(cdf_rows, xi)
    return _sample_rows(cdf_rows, xi, interpret=_interpret())


def forest_sample(forest: RadixForest, xi: jax.Array, use_pallas: bool = True) -> jax.Array:
    """Shared-distribution Algorithm 2 over a batch of uniforms."""
    if not use_pallas:
        return ref.ref_forest_sample(
            forest.cdf, forest.table, forest.left, forest.right, xi
        )
    return _forest_sample(
        forest.cdf, forest.table, forest.left, forest.right, xi,
        interpret=_interpret(),
    )


def forest_delta(data: jax.Array, m: int, use_pallas: bool = True) -> jax.Array:
    """Separator distances for forest construction."""
    if not use_pallas:
        return ref.ref_forest_delta(data, m)
    return _forest_delta(data, m, interpret=_interpret())
