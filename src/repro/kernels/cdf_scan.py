"""Fused softmax -> CDF Pallas kernel (the inversion-method setup hot path).

For LM decode we must turn a row of logits (vocab up to ~202k) into a
normalized CDF every step. Doing softmax and cumsum as separate XLA ops costs
three HBM round-trips of the (B, V) tensor; this kernel fuses exponentiation,
normalization and the prefix scan into one pass over VMEM-resident tiles with
a per-row running carry (TPU grids iterate the trailing axis sequentially, so
the carry lives in VMEM scratch).

Two phases (two `pallas_call`s):
  1. row stats: running max/sum-of-exp (online-softmax style rescaling), or a
     plain sum for the weights->CDF case (the paper's construction input);
  2. scan: normalized exp + running prefix, emitting the inclusive CDF.

Tiling: rows x vocab blocks of (R, T); T a multiple of 128 (lane width), R a
multiple of 8 (sublanes, f32). VMEM working set = 2*R*T*4B + carries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _stats_kernel(x_ref, m_ref, s_ref, mc_ref, sc_ref, *, softmax: bool):
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _():
        mc_ref[...] = jnp.full_like(mc_ref, NEG_INF if softmax else 0.0)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    x = x_ref[...].astype(jnp.float32)
    if softmax:
        m_prev = mc_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1, keepdims=True))
        s_new = sc_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.exp(x - m_new), axis=-1, keepdims=True
        )
        mc_ref[...] = m_new
        sc_ref[...] = s_new
    else:
        sc_ref[...] = sc_ref[...] + jnp.sum(x, axis=-1, keepdims=True)

    @pl.when(j == nj - 1)
    def _():
        m_ref[...] = mc_ref[...]
        s_ref[...] = sc_ref[...]


def _scan_kernel(x_ref, m_ref, s_ref, o_ref, c_ref, *, softmax: bool):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        c_ref[...] = jnp.zeros_like(c_ref)

    x = x_ref[...].astype(jnp.float32)
    if softmax:
        e = jnp.exp(x - m_ref[...]) / s_ref[...]
    else:
        e = x / s_ref[...]
    c = jnp.cumsum(e, axis=-1) + c_ref[...]
    o_ref[...] = c
    c_ref[...] = c[:, -1:]


@functools.partial(
    jax.jit,
    static_argnames=("softmax", "normalize", "block_rows", "block_cols", "interpret"),
)
def cdf_scan(
    x: jax.Array,
    softmax: bool = True,
    normalize: bool = True,
    block_rows: int = 8,
    block_cols: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """(B, V) logits (softmax=True) or non-negative weights (False) ->
    (B, V) inclusive CDF rows, last element ~1.0 (leading 0 omitted).

    ``normalize=False`` (weights mode only) skips the stats pass and emits the
    raw inclusive row cumsum — the local scan of the distributed CDF build
    (``repro.dist.forest``): row totals are exchanged across devices and the
    carry is applied there, so the kernel must not divide."""
    if softmax and not normalize:
        raise ValueError("normalize=False requires softmax=False (raw cumsum)")
    B, V = x.shape
    R, T = block_rows, block_cols
    Bp = (B + R - 1) // R * R
    Vp = (V + T - 1) // T * T
    pad_val = NEG_INF if softmax else 0.0
    xp = jnp.pad(x, ((0, Bp - B), (0, Vp - V)), constant_values=pad_val)
    grid = (Bp // R, Vp // T)

    if normalize:
        m, s = pl.pallas_call(
            functools.partial(_stats_kernel, softmax=softmax),
            grid=grid,
            in_specs=[pl.BlockSpec((R, T), lambda i, j: (i, j))],
            out_specs=[
                pl.BlockSpec((R, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((R, 1), lambda i, j: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
                jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, 1), jnp.float32),
            ],
            interpret=interpret,
        )(xp)
    else:
        # raw mode: s == 1 makes the scan kernel's division exact identity
        m = jnp.zeros((Bp, 1), jnp.float32)
        s = jnp.ones((Bp, 1), jnp.float32)

    out = pl.pallas_call(
        functools.partial(_scan_kernel, softmax=softmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((R, T), lambda i, j: (i, j)),
            pl.BlockSpec((R, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((R, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((R, T), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Vp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((R, 1), jnp.float32)],
        interpret=interpret,
    )(xp, m, s)
    return out[:B, :V]
