"""Batched alias-table sampling over B stacked tables: the pool's bulk
PRNG drain.

The O(1)-per-draw counterpart of ``forest_sample.forest_sample_batched``:
lane ``q`` resolves uniform ``xi[q]`` in distribution ``dist_id[q]``'s
packed ``(q, alias)`` row with exactly two flat row-offset gathers and one
comparison — no descent, no loop. This is the Lehmann et al. (2021) packed
layout applied to the mixed-batch drain, and the reason the pool carries a
per-tenant *method*: this path is ~100x the forest drain's throughput but
non-monotone (it destroys QMC stratification — see the fig-1 discrepancy
bench), so only PRNG tenants route here.

Same lane conventions as the forest kernels: ``dist_id < 0`` marks a
sentinel (padding) lane resolving to 0 without touching any row (a freed
row's cleared table must never be read as live), and ``coalesce=True``
runs the stable sort-by-row bucketing pre-pass (elementwise identical
either way). The within-cell fraction is clamped into [0, 1) with the same
constant as :func:`repro.core.alias.sample_alias`, so ``xi == 1.0`` (an
upcast float64 uniform) behaves as the limit draw instead of
unconditionally taking the last cell's alias.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.alias import ALIAS_FRAC_MAX

from .forest_sample import _bucket_order


def _alias_sample_kernel(q_ref, a_ref, did_ref, xi_ref, o_ref, *, n: int):
    did_raw = did_ref[...]
    valid = did_raw >= 0
    did = jnp.where(valid, did_raw, 0)
    scaled = xi_ref[...] * jnp.float32(n)
    cell = jnp.clip(scaled.astype(jnp.int32), 0, n - 1)
    frac = jnp.clip(
        scaled - cell.astype(jnp.float32), 0.0, jnp.float32(ALIAS_FRAC_MAX)
    )
    flat = did * n + cell
    qv = jnp.take(q_ref[...].reshape(-1), flat)
    av = jnp.take(a_ref[...].reshape(-1), flat)
    o_ref[...] = jnp.where(valid, jnp.where(frac < qv, cell, av), 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret", "coalesce"))
def alias_sample_batched(
    q: jax.Array,
    alias: jax.Array,
    dist_id: jax.Array,
    xi: jax.Array,
    block: int = 2048,
    interpret: bool = True,
    coalesce: bool = True,
) -> jax.Array:
    """Bulk sampling over B stacked alias tables: ``(dist_id, xi)`` pairs
    (Q,) -> row-local indices (Q,) int32, one launch for the mixed batch.

    ``q`` (B, n) f32 / ``alias`` (B, n) i32 are the stacked
    ``BatchedAlias`` arrays; the whole stack stays VMEM-resident (8 bytes
    per cell — half a forest row) while lanes stream through in tiles.
    Lanes with ``dist_id < 0`` are sentinels resolved to 0; block padding
    uses them too. Elementwise equal to the float32 numpy oracle
    ``core.alias.np_sample_alias_f32`` (identical IEEE arithmetic)."""
    (Q,) = xi.shape
    B, n = q.shape
    Qp = (Q + block - 1) // block * block
    xip = jnp.pad(xi, (0, Qp - Q))
    didp = jnp.pad(
        jnp.minimum(dist_id.astype(jnp.int32), B - 1), (0, Qp - Q),
        constant_values=-1,
    )
    if coalesce:
        order, inv = _bucket_order(didp)
        didp, xip = didp[order], xip[order]
    full2 = lambda r, c: pl.BlockSpec((r, c), lambda i: (0, 0))
    lane = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_alias_sample_kernel, n=n),
        grid=(Qp // block,),
        in_specs=[full2(B, n), full2(B, n), lane, lane],
        out_specs=lane,
        out_shape=jax.ShapeDtypeStruct((Qp,), jnp.int32),
        interpret=interpret,
    )(q, alias.astype(jnp.int32), didp, xip)
    if coalesce:
        out = out[inv]
    return out[:Q]
