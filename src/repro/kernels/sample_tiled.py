"""Two-level tiled inverse-CDF search — the TPU-native Cutpoint Method.

Per-row decode sampling: each of B rows has its *own* CDF (from that row's
logits) and k uniforms. A GPU thread would binary-search with scattered
loads; a TPU lane cannot. The TPU-idiomatic equivalent of the paper's guide
table is *uniform-in-index* tiling: the last element of each T-wide tile is a
cutpoint; level 1 vector-compares xi against the V/T cutpoints, level 2
vector-compares within the one selected tile (a contiguous dynamic slice —
no gathers anywhere). Cost: O(V/T + T) vector ops instead of O(V), minimized
at T ~ sqrt(V); both levels are dense VPU compares, i.e. zero divergence —
the kernel-level realization of the paper's "all lanes finish together" goal.

CDF convention: row[i] = P_{i+1} (leading zero omitted, row[V-1] ~= 1), i.e.
the output of :mod:`repro.kernels.cdf_scan`. Returned index i satisfies
P_i <= xi < P_{i+1}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sample_kernel(cdf_ref, xi_ref, o_ref, *, tile: int, k: int):
    row = cdf_ref[...]                      # (1, Vp)
    V = row.shape[-1]
    nt = V // tile
    bounds = row.reshape(nt, tile)[:, -1]   # (nt,) tile cutpoints
    xis = xi_ref[...]                       # (1, k) — whole-block load only:
    # scalar int ref indexing (xi_ref[0, kk]) breaks the interpret-mode
    # discharge rule, and block loads are the TPU-native access pattern.
    out = []
    for kk in range(k):                     # k is small & static (usually 1)
        xi = xis[0, kk]
        t = jnp.sum((bounds <= xi).astype(jnp.int32))
        t = jnp.minimum(t, nt - 1)
        seg = pl.load(cdf_ref, (pl.dslice(0, 1), pl.dslice(t * tile, tile)))
        off = jnp.sum((seg[0] <= xi).astype(jnp.int32))
        out.append(t * tile + jnp.minimum(off, tile - 1))
    o_ref[...] = jnp.stack(out)[None, :]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sample_rows(
    cdf_rows: jax.Array,
    xi: jax.Array,
    tile: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """cdf_rows (B, V) inclusive CDFs, xi (B, k) uniforms -> (B, k) int32."""
    B, V = cdf_rows.shape
    k = xi.shape[1]
    Vp = (V + tile - 1) // tile * tile
    # pad with +inf-like sentinel: padded entries never counted as <= xi
    cp = jnp.pad(cdf_rows, ((0, 0), (0, Vp - V)), constant_values=2.0)
    out = pl.pallas_call(
        functools.partial(_sample_kernel, tile=tile, k=k),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, Vp), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.int32),
        interpret=interpret,
    )(cp, xi)
    return jnp.minimum(out, V - 1)
