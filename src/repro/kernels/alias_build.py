"""Batched split-and-pack alias construction: B tables in one launch.

The paper notes that known alias-table builds are serial; the pool's
admission waves need thousands of small tables built concurrently. This
kernel vectorizes :func:`repro.core.alias.build_alias_parallel`'s geometric
formulation over a stacked ``(B, n)`` weight matrix — the construction twin
of ``pool/batched.py``'s fused forest build, feeding the packed
:class:`~repro.pool.batched.BatchedAlias` arenas that Lehmann et al. (2021)
show batched GPU sampling wants.

The formulation is **positional**, which is what makes it kernel-shaped:
instead of compacting lights/heavies onto separate tapes (a scatter), the
demand/supply prefixes are cumsums of *masked* per-cell terms over the
original cell order, then pinned bit-flat between member cells by an
exactly-associative ``cummax`` over member-only values (XLA's cumsum is a
reassociated parallel scan, so a raw positional prefix can wobble by 1 ulp
across a ``+0.0`` term). Because the pinned tapes only increase at member
cells, a binary search over them lands directly on the ORIGINAL index of
the covering heavy. The whole build is then two cumsums, two cummaxes,
three fixed-trip binary searches, and elementwise selects: no scatter, no
sort, no data-dependent shapes.

Boundary policy matches the fixed host build exactly: zero-surplus heavies
(``n*p == 1``) supply an empty interval, owe no debt (``surplus > 0``
gates it), and are skipped by the strictly-greater searches, so exact
dyadic weights — where supply ends coincide with demand boundaries — pack
without breaking the telescoping-mass invariant. The jnp reference
(:func:`repro.kernels.ref.ref_alias_build_batched`) calls the SAME row
core, so kernel/ref agreement is structural, and the dyadic differential
tests additionally pin both against ``build_alias_parallel`` row by row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed trip count for the branchless binary searches (covers any n < 2^32;
# same convention as the forest kernels' 32-trip bisection).
_SEARCH_TRIPS = 32


def _row_searchsorted(a: jax.Array, v: jax.Array, strict: bool) -> jax.Array:
    """Per-row ``searchsorted`` with flat row-offset gathers, branchless.

    ``a`` (R, n) row-wise sorted, ``v`` (R, n) query per element -> (R, n)
    int32 in [0, n]: the first in-row position where ``a > v`` (``strict``,
    numpy's side="right") or ``a >= v`` (side="left"). Fixed ``fori_loop``
    trips with ``lo < hi``-guarded updates, so it is Pallas-safe and
    bit-exact against numpy (pure comparisons, no arithmetic on values)."""
    R, n = a.shape
    a_flat = a.reshape(-1)
    base = (jnp.arange(R, dtype=jnp.int32) * n)[:, None]
    lo = jnp.zeros(v.shape, jnp.int32)
    hi = jnp.full(v.shape, n, jnp.int32)

    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) >> 1
        am = jnp.take(a_flat, base + jnp.minimum(mid, n - 1))
        go_right = (am <= v) if strict else (am < v)
        nlo = jnp.where(go_right, mid + 1, lo)
        nhi = jnp.where(go_right, hi, mid)
        return jnp.where(active, nlo, lo), jnp.where(active, nhi, hi)

    lo, _ = jax.lax.fori_loop(0, _SEARCH_TRIPS, body, (lo, hi))
    return lo


def _row_take(a: jax.Array, idx: jax.Array) -> jax.Array:
    """Row-local gather via flat offsets (the packed-table idiom)."""
    R, n = a.shape
    base = (jnp.arange(R, dtype=jnp.int32) * n)[:, None]
    return jnp.take(a.reshape(-1), base + idx)


def alias_split_pack_rows(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """The shared build core: (R, n) weights -> ``(q, alias)`` (R, n) rows.

    Both the Pallas kernel body and the jnp reference run THIS function, so
    their agreement is structural. Rows are independent; zero-weight cells
    (the pool's padding) become full-deficit lights with ``q == 0`` — no
    draw ever resolves own-side in one, and they are never heavy so never
    an alias target — so padded cells are unreachable, exactly like the
    forest arena's zero-width intervals."""
    R, n = w.shape
    pos = jnp.arange(n, dtype=jnp.int32)[None, :]
    wsum = jnp.sum(w, axis=-1, keepdims=True)
    npi = w / wsum * jnp.float32(n)
    light = npi < 1.0
    heavy = ~light
    dvals = jnp.where(light, 1.0 - npi, 0.0)   # per-cell demand (lights)
    svals = jnp.where(heavy, npi - 1.0, 0.0)   # per-cell surplus (heavies)
    D = jnp.cumsum(dvals, axis=-1)             # positional demand prefix
    S = jnp.cumsum(svals, axis=-1)             # positional supply prefix
    # Pin tape flatness between member cells: XLA's cumsum is a reassociated
    # parallel scan, so the prefix can wobble by 1 ulp across a +0.0 term —
    # enough for a strict search to land on a NON-member position (a heavy's
    # debt aliased to a light). max is exactly associative, so propagating
    # each member's own prefix with a cummax makes flat segments bit-flat by
    # construction; member positions keep their own cumsum value.
    ninf = jnp.float32(-jnp.inf)
    D = jax.lax.cummax(jnp.where(light, D, ninf), axis=1)
    S = jax.lax.cummax(jnp.where(heavy, S, ninf), axis=1)
    total = jnp.minimum(D[:, -1:], S[:, -1:])
    has_both = jnp.any(light, axis=-1, keepdims=True) & jnp.any(
        heavy, axis=-1, keepdims=True
    )
    last_heavy = jnp.maximum(
        jnp.max(jnp.where(heavy, pos, -1), axis=-1, keepdims=True), 0
    )

    # lights: alias = the heavy whose supply interval contains the START of
    # the light's demand interval. The positional prefix only increases at
    # positive-surplus heavies, so the first strictly-greater position IS
    # that heavy's original index (zero-surplus heavies never cross).
    p_light = _row_searchsorted(S, D - dvals, strict=True)
    alias_light = jnp.where(p_light < n, jnp.minimum(p_light, n - 1), last_heavy)

    # heavies: where a heavy's own supply ends inside a light's demand
    # interval it owes the remainder (debt) to the next supplying heavy.
    x = S
    pj = _row_searchsorted(D, x, strict=False)
    inside = (pj < n) & (x < total) & (svals > 0.0)
    Dj = _row_take(D, jnp.minimum(pj, n - 1))
    debt = jnp.clip(jnp.where(inside, Dj - x, 0.0), 0.0, 1.0)
    p_nxt = _row_searchsorted(S, x, strict=True)
    nxt = jnp.where(p_nxt < n, jnp.minimum(p_nxt, n - 1), last_heavy)
    alias_heavy = jnp.where(debt > 0.0, nxt, pos)

    q = jnp.where(light, npi, 1.0 - debt)
    alias = jnp.where(light, alias_light, alias_heavy)
    # rows without both sides (exactly uniform, or single-sided rounding)
    # are already exact: the identity table
    q = jnp.where(has_both, q, jnp.ones_like(q))
    alias = jnp.where(has_both, alias, jnp.broadcast_to(pos, alias.shape))
    return q.astype(jnp.float32), alias.astype(jnp.int32)


def _alias_build_kernel(w_ref, q_ref, a_ref):
    q, a = alias_split_pack_rows(w_ref[...])
    q_ref[...] = q
    a_ref[...] = a


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def alias_build_batched(
    weights: jax.Array, block_b: int = 8, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """(B, n) stacked weights -> packed ``(q, alias)`` (B, n) f32/i32 stacks.

    Grid over row blocks; each program instance packs ``block_b`` whole
    rows from VMEM (rows are independent, so blocking cannot change bits).
    The batch is padded with uniform rows to a ``block_b`` multiple and
    trimmed on the way out."""
    B, n = weights.shape
    Bp = (B + block_b - 1) // block_b * block_b
    wp = jnp.pad(
        jnp.asarray(weights, jnp.float32), ((0, Bp - B), (0, 0)),
        constant_values=1.0,  # padding rows: uniform => identity tables
    )
    q, a = pl.pallas_call(
        _alias_build_kernel,
        grid=(Bp // block_b,),
        in_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Bp, n), jnp.float32),
            jax.ShapeDtypeStruct((Bp, n), jnp.int32),
        ),
        interpret=interpret,
    )(wp)
    return q[:B], a[:B]
