"""Forest-construction distance kernel (Algorithm 1's per-element work).

Computes the separator distance array that fully determines the radix forest:
``delta(k) = bits(data[k]) XOR bits(data[k+1])``, clamped to the sentinel
where the two lower bounds fall into different guide cells (the paper's
"setting the distance to the maximum"). Pure elementwise VPU work (bitcasts,
XOR, floor) — the O(n) hot loop of construction; the nearest-greater descent
that consumes it stays in XLA (see core.forest).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bits import DIST_SENTINEL


def _delta_kernel(a_ref, b_ref, o_ref, *, m: int):
    a = a_ref[...]
    b = b_ref[...]
    bits_a = jax.lax.bitcast_convert_type(a, jnp.uint32)
    bits_b = jax.lax.bitcast_convert_type(b, jnp.uint32)
    raw = bits_a ^ bits_b
    # Clip exactly like core.forest._cells. A crossing flagged here that the
    # tree builder does not see would diverge the forests bitwise; the
    # bit-identity contract must not rest on a rounding argument about
    # whether floor(data * m) can ever reach m.
    ca = jnp.clip(jnp.floor(a * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    cb = jnp.clip(jnp.floor(b * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    o_ref[...] = jnp.where(ca != cb, jnp.uint32(DIST_SENTINEL), raw)


@functools.partial(jax.jit, static_argnames=("m", "block", "interpret"))
def forest_delta(
    data: jax.Array, m: int, block: int = 1024, interpret: bool = True
) -> jax.Array:
    """data (n,) f32 increasing lower bounds -> (n-1,) uint32 distances."""
    n = data.shape[0]
    s = n - 1
    sp = max((s + block - 1) // block * block, block)
    a = jnp.pad(data[:-1], (0, sp - s))
    b = jnp.pad(data[1:], (0, sp - s))
    out = pl.pallas_call(
        functools.partial(_delta_kernel, m=m),
        grid=(sp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.uint32),
        interpret=interpret,
    )(a, b)
    return out[:s]


def _changed_kernel(a_ref, b_ref, o_ref):
    bits_a = jax.lax.bitcast_convert_type(a_ref[...], jnp.uint32)
    bits_b = jax.lax.bitcast_convert_type(b_ref[...], jnp.uint32)
    o_ref[...] = (bits_a != bits_b).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m", "block", "interpret"))
def forest_delta_update(
    data_old: jax.Array,
    data_new: jax.Array,
    m: int,
    block: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Algorithm-1 re-work for a weight update, in one elementwise pass.

    Returns ``(delta_new, leaf_changed)``: the (n-1,) separator distances of
    the *new* lower bounds (identical bits to :func:`forest_delta` on
    ``data_new``) and the (n,) mask of leaves whose float32 *bit pattern*
    moved. A cell (and hence the shard owning it) only needs its trees
    rebuilt when one of its leaves' bits moved — tree topology is a pure
    function of the bit patterns — so this mask is exactly the dirtiness
    signal the sharded delta path needs.
    """
    n = data_old.shape[0]
    np_ = max((n + block - 1) // block * block, block)
    a = jnp.pad(data_old, (0, np_ - n))
    b = jnp.pad(data_new, (0, np_ - n))
    changed = pl.pallas_call(
        _changed_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.int32),
        interpret=interpret,
    )(a, b)
    return (
        forest_delta(data_new, m, block=block, interpret=interpret),
        changed[:n].astype(jnp.bool_),
    )
