"""Forest-construction distance kernel (Algorithm 1's per-element work).

Computes the separator distance array that fully determines the radix forest:
``delta(k) = bits(data[k]) XOR bits(data[k+1])``, clamped to the sentinel
where the two lower bounds fall into different guide cells (the paper's
"setting the distance to the maximum"). Pure elementwise VPU work (bitcasts,
XOR, floor) — the O(n) hot loop of construction; the nearest-greater descent
that consumes it stays in XLA (see core.forest).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bits import DIST_SENTINEL


def _delta_kernel(a_ref, b_ref, o_ref, *, m: int):
    a = a_ref[...]
    b = b_ref[...]
    bits_a = jax.lax.bitcast_convert_type(a, jnp.uint32)
    bits_b = jax.lax.bitcast_convert_type(b, jnp.uint32)
    raw = bits_a ^ bits_b
    ca = jnp.floor(a * jnp.float32(m)).astype(jnp.int32)
    cb = jnp.floor(b * jnp.float32(m)).astype(jnp.int32)
    o_ref[...] = jnp.where(ca != cb, jnp.uint32(DIST_SENTINEL), raw)


@functools.partial(jax.jit, static_argnames=("m", "block", "interpret"))
def forest_delta(
    data: jax.Array, m: int, block: int = 1024, interpret: bool = True
) -> jax.Array:
    """data (n,) f32 increasing lower bounds -> (n-1,) uint32 distances."""
    n = data.shape[0]
    s = n - 1
    sp = max((s + block - 1) // block * block, block)
    a = jnp.pad(data[:-1], (0, sp - s))
    b = jnp.pad(data[1:], (0, sp - s))
    out = pl.pallas_call(
        functools.partial(_delta_kernel, m=m),
        grid=(sp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.uint32),
        interpret=interpret,
    )(a, b)
    return out[:s]
