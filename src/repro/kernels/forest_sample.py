"""Algorithm 2 as a Pallas kernel: shared-distribution batch sampling.

The paper's primary workload: ONE distribution (environment map row, data
mixture, expert gate prior), MILLIONS of uniforms. Guide table + node arrays
+ CDF stay VMEM-resident (O(n) each; n = 2^20 f32 -> 4 MB/table); uniforms
stream through in tiles. The traversal runs as a fixed-trip predicated loop:
every lane advances until *all* lanes in the tile hit a leaf — the hardware
analogue of the paper's warp-synchronized cost (``average_32``), which is
precisely the quantity radix forests minimize, so the algorithm/hardware fit
is tighter on TPU than on the paper's GPUs.

Gathers (``jnp.take`` from VMEM) are the honest cost: one per lane per level.
Depth is bounded (<= ~34 for distinct float32 keys; build flags tied chains
into fallback cells which ops.py pre-resolves), so `depth` is static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _forest_kernel(
    cdf_ref, table_ref, left_ref, right_ref, *rest, depth: int, m: int, fb: bool
):
    if fb:
        cf_ref, fb_ref, xi_ref, o_ref = rest
    else:
        xi_ref, o_ref = rest
    xi = xi_ref[...]
    n = left_ref.shape[0]
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    j = jnp.take(table_ref[...], g, axis=0)
    cdf = cdf_ref[...]
    left = left_ref[...]
    right = right_ref[...]

    if fb:
        # Pre-resolve lanes in degenerate cells by balanced index bisection
        # (the paper's logarithmic-worst-case guard) — without this, tied
        # zero-width chains exceed any fixed `depth` and the descent below
        # returns an unresolved internal node. The SAME _bisect as
        # core.sample.sample_forest, so elementwise agreement is structural.
        from repro.core.sample import _bisect

        flagged = (jnp.take(fb_ref[...], g, axis=0) > 0) & (j >= 0)
        cf = cf_ref[...]
        bal = _bisect(cdf, xi, jnp.take(cf, g, axis=0), jnp.take(cf, g + 1, axis=0), 32)
        j = jnp.where(flagged, ~bal, j)

    def body(_, j):
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < jnp.take(cdf, jj, axis=0)
        nxt = jnp.where(go_left, jnp.take(left, jj, axis=0), jnp.take(right, jj, axis=0))
        return jnp.where(j >= 0, nxt, j)

    j = jax.lax.fori_loop(0, depth, body, j)
    o_ref[...] = ~j


@functools.partial(jax.jit, static_argnames=("depth", "block", "interpret"))
def forest_sample(
    cdf: jax.Array,
    table: jax.Array,
    left: jax.Array,
    right: jax.Array,
    xi: jax.Array,
    cell_first: jax.Array | None = None,
    fallback: jax.Array | None = None,
    depth: int = 40,
    block: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    """Batch Algorithm 2. xi (B,) -> interval indices (B,) int32.

    Passing ``cell_first``/``fallback`` (as built by ``build_forest``)
    enables the degenerate-cell pre-resolution; without them the fixed-trip
    descent can return garbage for flagged cells (tied-weight chains deeper
    than ``depth``)."""
    (B,) = xi.shape
    m = table.shape[0]
    n = left.shape[0]
    fb = cell_first is not None and fallback is not None
    Bp = (B + block - 1) // block * block
    xip = jnp.pad(xi, (0, Bp - B))
    full = lambda size: pl.BlockSpec((size,), lambda i: (0,))
    in_specs = [full(n + 1), full(m), full(n), full(n)]
    operands = [cdf, table, left, right]
    if fb:
        in_specs += [full(m + 1), full(m)]
        operands += [cell_first, fallback.astype(jnp.int32)]
    in_specs.append(pl.BlockSpec((block,), lambda i: (i,)))
    operands.append(xip)
    out = pl.pallas_call(
        functools.partial(_forest_kernel, depth=depth, m=m, fb=fb),
        grid=(Bp // block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:B]
