"""Algorithm 2 as a Pallas kernel: shared-distribution batch sampling.

The paper's primary workload: ONE distribution (environment map row, data
mixture, expert gate prior), MILLIONS of uniforms. Guide table + node arrays
+ CDF stay VMEM-resident (O(n) each; n = 2^20 f32 -> 4 MB/table); uniforms
stream through in tiles. The traversal runs as a fixed-trip predicated loop:
every lane advances until *all* lanes in the tile hit a leaf — the hardware
analogue of the paper's warp-synchronized cost (``average_32``), which is
precisely the quantity radix forests minimize, so the algorithm/hardware fit
is tighter on TPU than on the paper's GPUs.

Gathers (``jnp.take`` from VMEM) are the honest cost: one per lane per level.
Depth is bounded (<= ~34 for distinct float32 keys; build flags tied chains
into fallback cells which ops.py pre-resolves), so `depth` is static.

:func:`forest_sample_batched` is the multi-distribution twin (the
``repro.pool`` serving workload): B stacked forests resident at once, each
lane routed into its own tree by a per-lane ``dist_id`` row offset. Two
serving-path refinements live here:

* **Coalesced bucketing pre-pass** (``coalesce=True``): lanes are stably
  sorted by owning tree inside the jitted program before the kernel runs, so
  each tile walks draws against one (or few) trees — Steele & Tristan's
  butterfly-partial-sum observation applied to the mixed-batch drain: the
  scattered-gather traffic of an unsorted drain is the memory bottleneck.
  Results are scattered back through the inverse permutation, so the output
  is elementwise identical to the unsorted descent (the per-lane walk is
  order-independent), and differential tests compare both.
* **Sentinel lanes**: ``dist_id < 0`` marks a padding lane. Sentinel lanes
  start at leaf ``~0`` and never descend, so block-size padding cannot walk
  a freed (stale) row's tree. The dispatchers pad with the sentinel.

:func:`forest_sample_batched_streams` is the stream-aware drain: instead of
host-computed uniforms it takes per-lane QMC counter values and
Cranley-Patterson offset bits, and computes the base-2 radical inverse and
rotation *in-kernel* (exact 24-bit integer pipeline, ``core.lds.qmc_bits24``)
— the pool's full drain then needs no host-side uniform generation at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.lds import QMC_SCALE, qmc_bits24


def _forest_kernel(
    cdf_ref, table_ref, left_ref, right_ref, *rest, depth: int, m: int, fb: bool
):
    if fb:
        cf_ref, fb_ref, xi_ref, o_ref = rest
    else:
        xi_ref, o_ref = rest
    xi = xi_ref[...]
    n = left_ref.shape[0]
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    j = jnp.take(table_ref[...], g, axis=0)
    cdf = cdf_ref[...]
    left = left_ref[...]
    right = right_ref[...]

    if fb:
        # Pre-resolve lanes in degenerate cells by balanced index bisection
        # (the paper's logarithmic-worst-case guard) — without this, tied
        # zero-width chains exceed any fixed `depth` and the descent below
        # returns an unresolved internal node. The SAME _bisect as
        # core.sample.sample_forest, so elementwise agreement is structural.
        from repro.core.sample import _bisect

        flagged = (jnp.take(fb_ref[...], g, axis=0) > 0) & (j >= 0)
        cf = cf_ref[...]
        bal = _bisect(cdf, xi, jnp.take(cf, g, axis=0), jnp.take(cf, g + 1, axis=0), 32)
        j = jnp.where(flagged, ~bal, j)

    def body(_, j):
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < jnp.take(cdf, jj, axis=0)
        nxt = jnp.where(go_left, jnp.take(left, jj, axis=0), jnp.take(right, jj, axis=0))
        return jnp.where(j >= 0, nxt, j)

    j = jax.lax.fori_loop(0, depth, body, j)
    o_ref[...] = ~j


def _forest_batched_kernel(
    cdf_ref, table_ref, left_ref, right_ref, *rest,
    depth: int, m: int, n: int, fb: bool, stream: bool,
):
    """Mixed-batch descent: lane q walks distribution dist_id[q]'s tree.

    The stacked tables stay VMEM-resident as full (B, ...) blocks; each lane
    resolves its own row by flat row-offset gathers (``dist * stride + idx``)
    — the packed-table trick that makes batched GPU sampling fast (Lehmann
    et al. 2021), here with the row id varying per lane so ONE launch drains
    draws against every distribution in the batch.

    ``dist_id < 0`` marks a sentinel (padding) lane: it resolves to leaf
    ``~0`` immediately, without walking any row's tree (a freed row's stale
    arrays must never be descended — after an evict they can hold tied
    chains deeper than ``depth`` with their fallback flags cleared).

    With ``stream=True`` the lane inputs are per-lane QMC counter values and
    24-bit Cranley-Patterson offsets instead of uniforms; the base-2 radical
    inverse + rotation run in-kernel (exact integer ops) and the kernel also
    writes the points it drew, so the host oracle can be asserted bit-equal.
    """
    if stream:
        if fb:
            cf_ref, fb_ref, did_ref, ctr_ref, off_ref, o_ref, xi_ref = rest
        else:
            did_ref, ctr_ref, off_ref, o_ref, xi_ref = rest
        xi = qmc_bits24(ctr_ref[...], off_ref[...]).astype(jnp.float32) * QMC_SCALE
        xi_ref[...] = xi
    else:
        if fb:
            cf_ref, fb_ref, did_ref, xi_ref_in, o_ref = rest
        else:
            did_ref, xi_ref_in, o_ref = rest
        xi = xi_ref_in[...]
    did_raw = did_ref[...]
    valid = did_raw >= 0
    did = jnp.where(valid, did_raw, 0)
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    cdf = cdf_ref[...].reshape(-1)      # (B*(n+1),)
    left = left_ref[...].reshape(-1)    # (B*n,)
    right = right_ref[...].reshape(-1)
    cbase = did * (n + 1)               # per-lane row offsets
    nbase = did * n
    # sentinel lanes start AT a leaf (~0 == -1): the descent below is inert
    j = jnp.where(valid, jnp.take(table_ref[...].reshape(-1), did * m + g), -1)

    if fb:
        # Same degenerate-cell pre-resolution as the shared-distribution
        # kernel, bisecting each lane's own CDF row (row-local indices).
        flagged = (jnp.take(fb_ref[...].reshape(-1), did * m + g) > 0) & (j >= 0)
        cf = cf_ref[...].reshape(-1)    # (B*(m+1),)
        lo = jnp.take(cf, did * (m + 1) + g)
        hi = jnp.take(cf, did * (m + 1) + g + 1)

        def bisect_body(_, state):
            lo, hi = state
            mid = (lo + hi + 1) >> 1
            ge = xi >= jnp.take(cdf, cbase + mid)
            return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid - 1)

        lo, _ = jax.lax.fori_loop(0, 32, bisect_body, (lo, hi))
        j = jnp.where(flagged, ~lo, j)

    def body(_, j):
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < jnp.take(cdf, cbase + jj)
        nxt = jnp.where(
            go_left, jnp.take(left, nbase + jj), jnp.take(right, nbase + jj)
        )
        return jnp.where(j >= 0, nxt, j)

    j = jax.lax.fori_loop(0, depth, body, j)
    o_ref[...] = ~j


def _bucket_order(did: jax.Array) -> tuple[jax.Array, jax.Array]:
    """The coalescing pre-pass: a stable sort by owning tree. Returns the
    gather permutation and its inverse scatter permutation. Stability keeps
    the within-tree draw order, so the tiles walk contiguous per-tree runs
    (sentinel lanes, ``did < 0``, group in front — they never descend)."""
    order = jnp.argsort(did, stable=True)
    inv = jnp.argsort(order, stable=True)
    return order, inv


@functools.partial(
    jax.jit, static_argnames=("depth", "block", "interpret", "coalesce")
)
def forest_sample_batched(
    cdf: jax.Array,
    table: jax.Array,
    left: jax.Array,
    right: jax.Array,
    dist_id: jax.Array,
    xi: jax.Array,
    cell_first: jax.Array | None = None,
    fallback: jax.Array | None = None,
    depth: int = 40,
    block: int = 2048,
    interpret: bool = True,
    coalesce: bool = True,
) -> jax.Array:
    """Bulk sampling over B stacked forests: ``(dist_id, xi)`` pairs (Q,) ->
    row-local interval indices (Q,) int32, one launch for the mixed batch.

    Inputs are the stacked ``BatchedForest`` arrays (``cdf`` (B, n+1),
    ``table`` (B, m), ``left``/``right`` (B, n), optionally ``cell_first``
    (B, m+1) / ``fallback`` (B, m) for degenerate-cell pre-resolution —
    required whenever any row flagged a cell). VMEM budget is the whole
    stack (~B * n * 16B), which is exactly the pool's size-class regime:
    many small distributions sharing one resident table.

    ``dist_id < 0`` lanes are sentinels: resolved to 0 without descending
    any tree (block padding uses them too). ``coalesce=True`` (default)
    runs the bucketing pre-pass — stable sort by tree, descend coalesced
    per-tree tiles, scatter back — elementwise identical to the scattered
    walk; ``coalesce=False`` keeps the scattered order (the bench contrast).
    """
    (Q,) = xi.shape
    B, m = table.shape
    n = left.shape[1]
    fb = cell_first is not None and fallback is not None
    Qp = (Q + block - 1) // block * block
    xip = jnp.pad(xi, (0, Qp - Q))
    didp = jnp.pad(
        jnp.minimum(dist_id.astype(jnp.int32), B - 1), (0, Qp - Q),
        constant_values=-1,
    )
    if coalesce:
        order, inv = _bucket_order(didp)
        didp, xip = didp[order], xip[order]
    full2 = lambda r, c: pl.BlockSpec((r, c), lambda i: (0, 0))
    in_specs = [full2(B, n + 1), full2(B, m), full2(B, n), full2(B, n)]
    operands = [cdf, table, left, right]
    if fb:
        in_specs += [full2(B, m + 1), full2(B, m)]
        operands += [cell_first, fallback.astype(jnp.int32)]
    in_specs += [
        pl.BlockSpec((block,), lambda i: (i,)),
        pl.BlockSpec((block,), lambda i: (i,)),
    ]
    operands += [didp, xip]
    out = pl.pallas_call(
        functools.partial(
            _forest_batched_kernel, depth=depth, m=m, n=n, fb=fb,
            stream=False,
        ),
        grid=(Qp // block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Qp,), jnp.int32),
        interpret=interpret,
    )(*operands)
    if coalesce:
        out = out[inv]
    return out[:Q]


@functools.partial(
    jax.jit, static_argnames=("depth", "block", "interpret", "coalesce")
)
def forest_sample_batched_streams(
    cdf: jax.Array,
    table: jax.Array,
    left: jax.Array,
    right: jax.Array,
    dist_id: jax.Array,
    counter: jax.Array,
    offset_bits: jax.Array,
    cell_first: jax.Array | None = None,
    fallback: jax.Array | None = None,
    depth: int = 40,
    block: int = 2048,
    interpret: bool = True,
    coalesce: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """The stream-aware bulk drain: per-lane QMC state in, draws out.

    Like :func:`forest_sample_batched`, but the lane inputs are
    ``counter`` (Q,) uint32 — each lane's already-rank-adjusted stream
    counter — and ``offset_bits`` (Q,) uint32 — its slot's 24-bit
    Cranley-Patterson rotation. The base-2 radical inverse and rotation run
    *in-kernel* (exact integer pipeline), so no uniform ever materializes on
    the host. Returns ``(idx, xi)`` — the resolved row-local interval
    indices and the exact float32 stream points the kernel drew (bit-equal
    to the host ``QmcStreams`` oracle; the differential suite asserts it).
    Sentinel lanes (``dist_id < 0``) resolve to 0 and still report their
    (unused) point."""
    (Q,) = counter.shape
    B, m = table.shape
    n = left.shape[1]
    fb = cell_first is not None and fallback is not None
    Qp = (Q + block - 1) // block * block
    ctrp = jnp.pad(counter.astype(jnp.uint32), (0, Qp - Q))
    offp = jnp.pad(offset_bits.astype(jnp.uint32), (0, Qp - Q))
    didp = jnp.pad(
        jnp.minimum(dist_id.astype(jnp.int32), B - 1), (0, Qp - Q),
        constant_values=-1,
    )
    if coalesce:
        order, inv = _bucket_order(didp)
        didp, ctrp, offp = didp[order], ctrp[order], offp[order]
    full2 = lambda r, c: pl.BlockSpec((r, c), lambda i: (0, 0))
    in_specs = [full2(B, n + 1), full2(B, m), full2(B, n), full2(B, n)]
    operands = [cdf, table, left, right]
    if fb:
        in_specs += [full2(B, m + 1), full2(B, m)]
        operands += [cell_first, fallback.astype(jnp.int32)]
    lane = pl.BlockSpec((block,), lambda i: (i,))
    in_specs += [lane, lane, lane]
    operands += [didp, ctrp, offp]
    out, xi = pl.pallas_call(
        functools.partial(
            _forest_batched_kernel, depth=depth, m=m, n=n, fb=fb,
            stream=True,
        ),
        grid=(Qp // block,),
        in_specs=in_specs,
        out_specs=(lane, lane),
        out_shape=(
            jax.ShapeDtypeStruct((Qp,), jnp.int32),
            jax.ShapeDtypeStruct((Qp,), jnp.float32),
        ),
        interpret=interpret,
    )(*operands)
    if coalesce:
        out, xi = out[inv], xi[inv]
    return out[:Q], xi[:Q]


@functools.partial(jax.jit, static_argnames=("depth", "block", "interpret"))
def forest_sample(
    cdf: jax.Array,
    table: jax.Array,
    left: jax.Array,
    right: jax.Array,
    xi: jax.Array,
    cell_first: jax.Array | None = None,
    fallback: jax.Array | None = None,
    depth: int = 40,
    block: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    """Batch Algorithm 2. xi (B,) -> interval indices (B,) int32.

    Passing ``cell_first``/``fallback`` (as built by ``build_forest``)
    enables the degenerate-cell pre-resolution; without them the fixed-trip
    descent can return garbage for flagged cells (tied-weight chains deeper
    than ``depth``)."""
    (B,) = xi.shape
    m = table.shape[0]
    n = left.shape[0]
    fb = cell_first is not None and fallback is not None
    Bp = (B + block - 1) // block * block
    xip = jnp.pad(xi, (0, Bp - B))
    full = lambda size: pl.BlockSpec((size,), lambda i: (0,))
    in_specs = [full(n + 1), full(m), full(n), full(n)]
    operands = [cdf, table, left, right]
    if fb:
        in_specs += [full(m + 1), full(m)]
        operands += [cell_first, fallback.astype(jnp.int32)]
    in_specs.append(pl.BlockSpec((block,), lambda i: (i,)))
    operands.append(xip)
    out = pl.pallas_call(
        functools.partial(_forest_kernel, depth=depth, m=m, fb=fb),
        grid=(Bp // block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:B]
