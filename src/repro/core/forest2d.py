"""Simultaneous multi-row forest construction (paper Sec. 5).

"Building multiple tables and trees simultaneously, e.g. for two-dimensional
distributions, is as simple as adding yet another criterion to the extended
check in Algorithm 1: if the index of the left or right neighbor goes beyond
the *index boundary* of a row, it is a leftmost or a rightmost node."

Here the criterion is folded into the cell id: with per-row guide tables of
m cells, a flat entry (row r, interval j) lives in cell ``r*m +
floor(cdf_r[j]*m)`` — row boundaries change the cell id, which already
clamps the separator distance to the sentinel. ONE data-parallel pass builds
every row tree of a 2-D distribution (H rows x W columns => H*W leaves, H*m
guide cells), with the same perfect load balancing as the 1-D case. This
replaces the per-row Python build loop in the env-map workload (paper's
target application: HDR environment maps, one CDF per image row).

Every per-row quantity is a pure function of that row's data (crossing
separators carry the sentinel distance, so the nearest-greater searches
never escape a row), which buys two properties the 2-D serving layer
(:mod:`repro.spatial`) builds on:

* **Per-row bit-identity.** Row ``r`` of the flat build carries exactly the
  arrays of an independent ``core.build_forest`` over that row's CDF —
  including the per-(row, cell) degenerate-cell ``fallback`` flags computed
  here with the same saturating parent-chase as the 1-D builder.
  :func:`repro.pool.batched.batched_from_row_forest` rewrites the flat
  global references into row-local ones and the result is bit-equal to B
  stacked single builds (the spatial conformance suite pins this), so the
  one-pass builder can feed the fixed-trip batched descent kernel
  (:func:`repro.kernels.forest_sample.forest_sample_batched`).
* **Row-sparse rebuilds.** Because rows never interact, rebuilding a dirty
  subset of rows and scattering the rows into a stacked forest is bit-equal
  to a from-scratch build of the whole stack — the ``update_map`` delta
  path of :class:`repro.spatial.Map2DSampler` rests on exactly this.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .bits import DIST_SENTINEL
from .cdf import lower_bounds
from .forest import _DEPTH_ITERS, INVALID, MAX_DEPTH, _nearest_greater
from .bits import float_to_bits


class RowForest(NamedTuple):
    data: jax.Array        # (R*W,) f32 flat lower bounds (per-row CDFs)
    table: jax.Array       # (R*m,) i32
    left: jax.Array        # (R*W,) i32
    right: jax.Array       # (R*W,) i32
    cell_first: jax.Array  # (R*m + 1,) i32 flat first-overlap per cell
    rows: int
    width: int
    m: int
    fallback: jax.Array | None = None  # (R*m,) bool degenerate (row, cell)


@functools.partial(jax.jit, static_argnames=("m", "fallback_slack"))
def build_forest_rows(
    cdf_rows: jax.Array, m: int, fallback_slack: int = 2
) -> RowForest:
    """cdf_rows (R, W+1) per-row CDFs -> all R forests in one pass."""
    R, W1 = cdf_rows.shape
    W = W1 - 1
    n = R * W
    data = lower_bounds(cdf_rows).reshape(n)            # (R*W,) in [0,1)
    local = jnp.clip(
        jnp.floor(data * jnp.float32(m)).astype(jnp.int32), 0, m - 1
    )
    rows = jnp.repeat(jnp.arange(R, dtype=jnp.int32), W)
    cells = rows * m + local                            # (R*W,) flat cells
    n_cells = R * m

    bits = float_to_bits(data)
    sep_raw = bits[:-1] ^ bits[1:]
    crossing = cells[:-1] != cells[1:]                  # includes row bounds
    sentinel = jnp.uint32(DIST_SENTINEL)
    d = jnp.where(crossing, sentinel, sep_raw)

    # first interval overlapping each (row, cell): per-row searchsorted
    grid = jnp.arange(m, dtype=jnp.float32) / jnp.float32(m)
    cf_local = jax.vmap(
        lambda row: jnp.searchsorted(row, grid, side="right").astype(jnp.int32) - 1
    )(data.reshape(R, W))
    cf = jnp.clip(cf_local, 0, W - 1) + (jnp.arange(R, dtype=jnp.int32) * W)[:, None]
    cell_first = jnp.concatenate([cf.reshape(-1), jnp.int32(n - 1)[None]])

    counts = jnp.zeros((n_cells,), jnp.int32).at[cells].add(1)
    first_leaf = jnp.full((n_cells,), n, jnp.int32).at[cells].min(
        jnp.arange(n, dtype=jnp.int32)
    )
    f_safe = jnp.clip(first_leaf, 0, n - 1)
    cell_start = (jnp.arange(n_cells, dtype=jnp.int32) % m).astype(jnp.float32) / m
    left_overlap = data[f_safe] > cell_start
    overlap = jnp.where(counts > 0, counts + left_overlap.astype(jnp.int32), 1)

    left = jnp.full((n,), INVALID, jnp.int32)
    right = jnp.full((n,), INVALID, jnp.int32)
    leaf_parent = jnp.full((n,), -1, jnp.int32)
    node_parent = jnp.full((n,), -1, jnp.int32)

    if n > 1:
        dL, _L, dR, _R = _nearest_greater(d)
        k = jnp.arange(n - 1, dtype=jnp.int32)
        in_cell = ~crossing
        is_root = in_cell & (dL == sentinel) & (dR == sentinel)
        par_is_L = dL <= dR
        parent_node = jnp.where(par_is_L, _L, _R) + 1
        node_id = k + 1
        wr = in_cell & ~is_root & par_is_L
        wl = in_cell & ~is_root & ~par_is_L
        right = right.at[jnp.where(wr, parent_node, n)].set(node_id, mode="drop")
        left = left.at[jnp.where(wl, parent_node, n)].set(node_id, mode="drop")
        node_parent = node_parent.at[
            jnp.where(in_cell & ~is_root, k + 1, n)
        ].set(parent_node, mode="drop")
        root_slot = first_leaf[cells[jnp.clip(k, 0, n - 1)]]
        right = right.at[jnp.where(is_root, root_slot, n)].set(node_id, mode="drop")
        node_parent = node_parent.at[jnp.where(is_root, k + 1, n)].set(
            root_slot, mode="drop"
        )

    i = jnp.arange(n, dtype=jnp.int32)
    if n > 1:
        dl = jnp.where(i > 0, d[jnp.clip(i - 1, 0)], sentinel)
        dr = jnp.where(i < n - 1, d[jnp.clip(i, 0, max(n - 2, 0))], sentinel)
    else:
        dl = jnp.full((n,), sentinel, jnp.uint32)
        dr = jnp.full((n,), sentinel, jnp.uint32)
    lone = (dl == sentinel) & (dr == sentinel)
    lpar_left = dl <= dr
    lparent = jnp.where(lpar_left, i, i + 1)
    right = right.at[jnp.where(~lone & lpar_left, lparent, n)].set(~i, mode="drop")
    left = left.at[jnp.where(~lone & ~lpar_left, lparent, n)].set(~i, mode="drop")
    right = right.at[jnp.where(lone, i, n)].set(~i, mode="drop")
    leaf_parent = jnp.where(lone, i, lparent)

    # manual left child: previous interval IN THE SAME ROW (clamp at row start)
    nonempty = counts > 0
    row_of_f = f_safe // W
    prev_in_row = jnp.maximum(f_safe - 1, row_of_f * W)
    left = left.at[jnp.where(nonempty, f_safe, n)].set(~prev_in_row, mode="drop")

    table = jnp.where(
        counts == 0, ~cell_first[:-1], jnp.where(overlap == 1, ~f_safe, f_safe)
    ).astype(jnp.int32)

    # Traversal depth per leaf -> per-(row, cell) fallback flags: the same
    # saturating parent chase as the 1-D builder (core.forest._build_cell_
    # trees), so the flags are bit-identical per row — chases never cross a
    # row because every parent edge stays inside its cell.
    depth = jnp.zeros((n,), jnp.int32)
    anc = leaf_parent
    for _ in range(_DEPTH_ITERS):
        live = anc >= 0
        depth = depth + live.astype(jnp.int32)
        anc = jnp.where(live, node_parent[jnp.clip(anc, 0)], anc)
    depth = depth + 1  # the leaf resolution step itself

    cell_depth = jnp.zeros((n_cells,), jnp.int32).at[cells].max(depth)
    allowed = jnp.ceil(jnp.log2(jnp.maximum(overlap, 2).astype(jnp.float32)))
    fallback = (overlap > 1) & (
        cell_depth > allowed.astype(jnp.int32) + fallback_slack
    )
    return RowForest(data, table, left, right, cell_first, R, W, m, fallback)


@functools.partial(jax.jit, static_argnames=())
def sample_forest_rows(f: RowForest, row: jax.Array, xi: jax.Array) -> jax.Array:
    """Sample column index within each lane's row: (rows (B,), xi (B,)) ->
    column ids (B,). Batched Algorithm 2 over the flat forest."""
    m, W = f.m, f.width
    n = f.left.shape[0]
    g = row * m + jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    j = f.table[g]

    def cond(state):
        j, it = state
        return jnp.any(j >= 0) & (it < MAX_DEPTH)

    def body(state):
        j, it = state
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < f.data[jj]
        nxt = jnp.where(go_left, f.left[jj], f.right[jj])
        return jnp.where(j >= 0, nxt, j), it + 1

    j, _ = jax.lax.while_loop(cond, body, (j, jnp.int32(0)))
    flat = ~j
    return flat - row * W   # column within the row


def validate_forest_rows(f: RowForest) -> None:
    """Structural invariants of the flat multi-row forest; AssertionError on
    violation. The 2-D twin of ``core.forest.validate_forest``: for every
    (row, cell) the guide entry must resolve within the row, and in-order
    traversal of a cell tree must enumerate the cell's leaves in increasing
    order prefixed by the row-clamped left-overlap leaf."""
    data = np.asarray(f.data)
    table = np.asarray(f.table)
    left = np.asarray(f.left)
    right = np.asarray(f.right)
    R, W, m = f.rows, f.width, f.m
    n = R * W
    local = np.clip(np.floor(data * np.float32(m)).astype(np.int64), 0, m - 1)
    cells = np.repeat(np.arange(R), W) * m + local

    for c in range(R * m):
        r = c // m
        ref = int(table[c])
        leaves = np.where(cells == c)[0]
        if ref < 0:
            i = ~ref
            assert r * W <= i < (r + 1) * W, (c, i)  # never leaves the row
            cell_start = (c % m) / m
            assert data[i] <= cell_start + 1e-7 or (
                len(leaves) == 1 and leaves[0] == i
            ), (c, i)
            continue
        got: list[int] = []
        depth_guard = 0

        def walk(j: int) -> None:
            nonlocal depth_guard
            depth_guard += 1
            assert depth_guard < 10_000
            if j < 0:
                got.append(~j)
                return
            assert 0 <= j < n
            walk(int(left[j]))
            walk(int(right[j]))

        walk(ref)
        f0 = int(leaves[0])
        expect = [max(f0 - 1, r * W)] + list(leaves)
        assert got == expect, (c, got, expect)
        assert all(r * W <= i < (r + 1) * W for i in got), (c, got)


def np_reference_rows(cdf_rows: np.ndarray, row: np.ndarray, xi: np.ndarray):
    """searchsorted oracle per lane."""
    out = np.empty(len(xi), np.int64)
    for i, (r, u) in enumerate(zip(row, xi)):
        out[i] = np.clip(
            np.searchsorted(cdf_rows[r][1:], u, side="right"),
            0, cdf_rows.shape[1] - 2,
        )
    return out
