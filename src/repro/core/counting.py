"""Instrumented numpy samplers with exact memory-load counting (Table 1).

Load-accounting model (matches the paper's):
  * guide-table lookup ............................ 1 load
  * tagged cell (~i, single overlapping interval) . 0 further loads
  * per bisection iteration (one cdf probe) ....... 1 load
  * per radix-tree node visit (children + split
    value, interleaved as the paper suggests) ..... 1 load
``warp_cost`` aggregates per-warp maxima: the cost of 32 lock-stepped lanes
is the slowest lane (the paper's ``average_32`` column).
"""
from __future__ import annotations

import numpy as np

from .forest import RadixForest, forest_to_numpy


def np_sample_binary_counting(cdf: np.ndarray, xi: np.ndarray):
    """Plain bisection over the whole CDF; returns (i, loads)."""
    n = len(cdf) - 1
    lo = np.zeros(len(xi), np.int64)
    hi = np.full(len(xi), n - 1, np.int64)
    loads = np.zeros(len(xi), np.int64)
    while np.any(lo < hi):
        act = lo < hi
        mid = (lo + hi + 1) >> 1
        probe = cdf[np.clip(mid, 0, n)]
        ge = xi >= probe
        loads += act
        lo = np.where(act & ge, mid, lo)
        hi = np.where(act & ~ge, mid - 1, hi)
    return lo, loads


def np_sample_cutpoint_binary_counting(
    cdf: np.ndarray, cell_first: np.ndarray, table: np.ndarray, xi: np.ndarray
):
    """Cutpooint + in-cell bisection with tagged single-interval cells."""
    m = len(cell_first) - 1
    n = len(cdf) - 1
    g = np.clip(np.floor(np.asarray(xi, np.float32) * np.float32(m)).astype(np.int64), 0, m - 1)
    loads = np.ones(len(xi), np.int64)  # the guide-table load
    ref = table[g]
    tagged = ref < 0
    out = np.where(tagged, ~ref, 0).astype(np.int64)

    lo = cell_first[g].astype(np.int64)
    hi = cell_first[g + 1].astype(np.int64)
    act0 = ~tagged
    lo = np.where(act0, lo, 0)
    hi = np.where(act0, hi, 0)
    while np.any((lo < hi) & act0):
        act = (lo < hi) & act0
        mid = (lo + hi + 1) >> 1
        probe = cdf[np.clip(mid, 0, n)]
        ge = xi >= probe
        loads += act
        lo = np.where(act & ge, mid, lo)
        hi = np.where(act & ~ge, mid - 1, hi)
    out = np.where(act0, lo, out)
    return out, loads


def np_sample_forest_counting(forest: RadixForest, xi: np.ndarray):
    """Algorithm 2 with per-lane node-visit counting; returns (i, loads)."""
    fn = forest_to_numpy(forest)
    cdf, table, left, right = fn["cdf"], fn["table"], fn["left"], fn["right"]
    n, m = len(left), len(table)
    g = np.clip(np.floor(np.asarray(xi, np.float32) * np.float32(m)).astype(np.int64), 0, m - 1)
    j = table[g].astype(np.int64)
    loads = np.ones(len(xi), np.int64)  # guide-table load
    guard = 0
    while np.any(j >= 0):
        act = j >= 0
        jj = np.clip(j, 0, n - 1)
        go_left = xi < cdf[jj]
        nxt = np.where(go_left, left[jj], right[jj])
        loads += act
        j = np.where(act, nxt, j)
        guard += 1
        assert guard < 20_000, "unterminated traversal"
    return ~j, loads


def warp_cost(loads: np.ndarray, warp: int = 32) -> float:
    """Mean over warps of the per-warp max load count (paper's average_32)."""
    k = (len(loads) // warp) * warp
    if k == 0:
        return float(loads.max(initial=0))
    return float(np.asarray(loads[:k]).reshape(-1, warp).max(axis=1).mean())


def table1_row(loads: np.ndarray) -> dict:
    return {
        "maximum": int(loads.max(initial=0)),
        "average": float(loads.mean()),
        "average_32": warp_cost(loads, 32),
    }
