"""Inverse-CDF samplers: the paper's Algorithm 2 plus every surveyed baseline.

All JAX samplers are batch-vectorized; divergence is handled by per-lane
predication inside a ``while_loop``, so the per-batch cost is the max lane
cost — exactly the warp-synchronized cost model (``average_32``) the paper
optimizes for. Numpy twins with exact *memory-load counting* live in
:mod:`repro.core.counting` and reproduce Table 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .forest import MAX_DEPTH, RadixForest


def sample_linear(cdf: jax.Array, xi: jax.Array) -> jax.Array:
    """O(n) linear scan (Sec. 2.1). For small n / reference only."""
    # i = #{k : cdf[k+1] <= xi}
    return jnp.sum(cdf[1:-1][None, :] <= xi[:, None], axis=-1).astype(jnp.int32)


def sample_binary(cdf: jax.Array, xi: jax.Array) -> jax.Array:
    """O(log n) bisection (Sec. 2.2)."""
    i = jnp.searchsorted(cdf[1:], xi, side="right").astype(jnp.int32)
    return jnp.clip(i, 0, cdf.shape[0] - 2)


def _bisect(cdf: jax.Array, xi: jax.Array, lo: jax.Array, hi: jax.Array, steps: int):
    """Find i in [lo, hi] with cdf[i] <= xi < cdf[i+1]; fixed-trip bisection."""

    def body(_, state):
        lo, hi = state
        mid = (lo + hi + 1) >> 1
        ge = xi >= cdf[mid]
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid - 1)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo


def sample_cutpoint_binary(
    cdf: jax.Array, cell_first: jax.Array, xi: jax.Array
) -> jax.Array:
    """Cutpoint Method with in-cell binary search (Sec. 2.5): O(1) average,
    O(log n) worst case. ``cell_first`` as built by the forest constructor
    ((m+1,), conservative last = first of next cell)."""
    m = cell_first.shape[0] - 1
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    lo = cell_first[g]
    hi = cell_first[g + 1]
    return _bisect(cdf, xi, lo, hi, 32)


def sample_cutpoint_linear(
    cdf: jax.Array, cell_first: jax.Array, xi: jax.Array, max_scan: int
) -> jax.Array:
    """Cutpoint Method with in-cell linear search (Sec. 2.5, original)."""
    m = cell_first.shape[0] - 1
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    i = cell_first[g]

    def body(_, i):
        done = xi < cdf[jnp.clip(i + 1, 0, cdf.shape[0] - 1)]
        return jnp.where(done, i, i + 1)

    return jax.lax.fori_loop(0, max_scan, body, i)


@functools.partial(jax.jit, static_argnames=("use_fallback", "unroll"))
def sample_forest(
    forest: RadixForest,
    xi: jax.Array,
    use_fallback: bool = True,
    unroll: int = 1,
) -> jax.Array:
    """Algorithm 2: guide-table lookup, then radix-tree descent.

    Node index doubles as CDF index: descend left iff ``xi < cdf[j]``.
    Leaf refs have the MSB set (two's complement ~i). Lanes in degenerate
    cells (``forest.fallback``) use balanced index bisection instead — the
    paper's logarithmic-worst-case guard.
    """
    cdf, table, left, right = forest.cdf, forest.table, forest.left, forest.right
    n = forest.n
    m = forest.m
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    j = table[g]

    if use_fallback:
        fb = forest.fallback[g] & (j >= 0)
        lo = forest.cell_first[g]
        hi = forest.cell_first[g + 1]
        bal = _bisect(cdf, xi, lo, hi, 32)
        j = jnp.where(fb, ~bal, j)  # pre-resolve fallback lanes

    def cond(state):
        j, it = state
        return jnp.any(j >= 0) & (it < MAX_DEPTH)

    def body(state):
        j, it = state
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < cdf[jj]
        nxt = jnp.where(go_left, left[jj], right[jj])
        return jnp.where(j >= 0, nxt, j), it + 1

    j, _ = jax.lax.while_loop(cond, body, (j, jnp.int32(0)))
    return ~j


def sample_forest_with_stats(forest: RadixForest, xi: jax.Array):
    """As :func:`sample_forest` but also returns per-lane node-visit counts
    (loads beyond the guide-table load) — the Table-1 instrumentation."""
    cdf, table, left, right = forest.cdf, forest.table, forest.left, forest.right
    n, m = forest.n, forest.m
    g = jnp.clip(jnp.floor(xi * jnp.float32(m)).astype(jnp.int32), 0, m - 1)
    j = table[g]

    def cond(state):
        j, _c, it = state
        return jnp.any(j >= 0) & (it < MAX_DEPTH)

    def body(state):
        j, c, it = state
        jj = jnp.clip(j, 0, n - 1)
        go_left = xi < cdf[jj]
        nxt = jnp.where(go_left, left[jj], right[jj])
        active = j >= 0
        return (
            jnp.where(active, nxt, j),
            c + active.astype(jnp.int32),
            it + 1,
        )

    j, c, _ = jax.lax.while_loop(cond, body, (j, jnp.zeros_like(g), jnp.int32(0)))
    return ~j, c
