"""Float bit-pattern utilities underlying radix-tree distance computations.

The paper's key trick (Binder & Keller 2019, Sec. 3.1): for IEEE-754 floats in
``[0, 1)`` the total order of values equals the total order of their bit
patterns interpreted as unsigned integers, so the bitwise XOR of two patterns
has its most significant set bit at the *level* of the implicit radix tree
(recursive bisection of ``[0,1)``) at which the two values part ways.
Comparing XOR values as unsigned ints therefore compares tree distances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel distance used where the paper requires "maximum distance":
#  * across guide-table cell boundaries (forest partition boundaries), and
#  * outside the global data range.
# Any XOR of two non-negative finite float32 patterns is <= 0x7fffffff, so
# 0xffffffff is strictly larger than every real distance.
#
# NOTE (divergence from the paper's *pseudocode*, following its *text*):
# Algorithm 1 sets the out-of-cell neighbor *value* to 1.0 to obtain a large
# distance. That only majorizes in-cell distances when cell boundaries are
# dyadic (power-of-two m). The text instead says "setting the distance ... to
# the maximum", which is robust for any m; we implement the text.
DIST_SENTINEL = np.uint32(0xFFFFFFFF)


def float_to_bits(x: jax.Array) -> jax.Array:
    """Bit pattern of float32 ``x`` as uint32."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def bits_to_float(b: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint32), jnp.float32)


def xor_distance(a: jax.Array, b: jax.Array) -> jax.Array:
    """Radix-tree distance of two float32 values in [0, 1) (compare as uint)."""
    return float_to_bits(a) ^ float_to_bits(b)


def np_float_to_bits(x: np.ndarray) -> np.ndarray:
    return np.asarray(x, np.float32).view(np.uint32)


def np_xor_distance(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np_float_to_bits(a) ^ np_float_to_bits(b)


def msb_index(x: np.ndarray) -> np.ndarray:
    """Index of the most significant set bit (numpy, for analysis/tests)."""
    x = np.asarray(x, np.uint32)
    out = np.full(x.shape, -1, np.int32)
    v = x.copy()
    for shift in (16, 8, 4, 2, 1):
        ge = v >= np.uint32(1 << shift)
        out = np.where(ge, out + shift, out)
        v = np.where(ge, v >> np.uint32(shift), v)
    out = np.where(x > 0, out + 1, -1)  # -1 for x == 0
    return out
