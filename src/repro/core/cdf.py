"""CDF construction: the inversion-method substrate.

``build_cdf`` turns weights into the partition 0 = P_0 < P_1 < ... < P_n = 1
(the paper's Sec. 1). On accelerators this is a parallel prefix sum — the very
operation the paper cites as the cheap, parallel part of inversion-method
setup (in contrast to the serial Alias-Method build). ``cdf_from_logits``
fuses a numerically stable softmax with the scan for LM decode.

The *interval lower bounds* used as radix-tree keys are ``cdf[:-1]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_ONE_MINUS_EPS = np.float32(np.nextafter(np.float32(1.0), np.float32(0.0)))

# Fixed reassociation grid of the prefix sum: the scan is ALWAYS computed as
# SCAN_CHUNKS independent row scans plus a serial carry over the chunk totals,
# no matter how many devices execute it. Any shard count dividing SCAN_CHUNKS
# then performs literally the same float additions (same row lengths, same
# carry chain), so the distributed scan in ``repro.dist.forest`` is bit-
# identical to this single-device path — which the forest needs, because tree
# topology depends on the *bit patterns* of the CDF (XOR distances).
# 64 is the max shard count exact bit-reproducible sharding supports (D | 64
# covers every pow2 mesh up to a 64-way data axis); growing past it only
# needs this constant raised — or the two-level carry hierarchy (chunk rows
# per device x devices) whose grid is shard-count-independent (ROADMAP).
SCAN_CHUNKS = 64


def normalize_weights(w: np.ndarray) -> np.ndarray:
    """Float64 normalization for high-dynamic-range weights.

    Distributions like the paper's ``p_i ~ i^20`` overflow float32 *before*
    normalization; normalize in float64 on host first, then feed float32.
    """
    w = np.asarray(w, np.float64)
    s = w.sum()
    if not np.isfinite(s) or s <= 0:
        raise ValueError("weights must be non-negative with a positive finite sum")
    return (w / s).astype(np.float32)


def updated_weights(raw, weights=None, delta=None):
    """New raw float64 weights + their normalized float32 form.

    The shared bookkeeping of in-place distribution updates
    (``ForestSampler.update_weights`` / ``MixtureSampler.update_weights``):
    pass new full ``weights``, or a ``delta`` added to the current ``raw``.
    """
    if (weights is None) == (delta is None):
        raise ValueError("pass exactly one of weights or delta")
    if weights is None:
        raw = np.asarray(raw, np.float64) + np.asarray(delta, np.float64)
    else:
        raw = np.asarray(weights, np.float64)
    return raw, normalize_weights(raw)


def scan_chunk_rows(w: jax.Array) -> jax.Array:
    """(n,) -> (SCAN_CHUNKS, L) zero-padded chunk rows — THE scan grid.

    Single-sourced on purpose: ``chunked_cumsum`` and the sharded feed in
    :mod:`repro.dist.forest` must agree on this layout exactly or the
    bit-identity contract between them silently breaks."""
    n = w.shape[0]
    L = -(-n // SCAN_CHUNKS)
    return jnp.pad(w, (0, SCAN_CHUNKS * L - n)).reshape(SCAN_CHUNKS, L)


def chunk_bounds(n: int) -> np.ndarray:
    """Element spans of the fixed scan-grid rows: row r covers [b[r], b[r+1]).

    The delta-update path (:func:`repro.dist.forest.update_forest_sharded`)
    patches the CDF through this exact grid — a weight change in row ``r``
    re-scans row ``r`` and re-derives the serial carry chain, never a
    different reassociation — so it uses these bounds to report which chunk
    rows a perturbation actually touched."""
    L = -(-n // SCAN_CHUNKS)
    return np.minimum(np.arange(SCAN_CHUNKS + 1, dtype=np.int64) * L, n)


def chunked_cumsum(w: jax.Array, row_scan=None) -> jax.Array:
    """Inclusive prefix sum over the fixed ``SCAN_CHUNKS`` reassociation grid.

    ``w`` (n,) is zero-padded into ``(SCAN_CHUNKS, L)`` rows; each row is
    scanned independently (``row_scan``, default row-wise ``jnp.cumsum``; the
    Pallas path in :mod:`repro.kernels.cdf_scan` is a drop-in), then a serial
    carry over the chunk totals is added back. Shard count never appears in
    the arithmetic — see the ``SCAN_CHUNKS`` note for why that matters.
    """
    n = w.shape[0]
    rows = scan_chunk_rows(w)
    local = jnp.cumsum(rows, axis=-1) if row_scan is None else row_scan(rows)
    totals = local[:, -1]
    carry = jnp.concatenate(
        [jnp.zeros((1,), local.dtype), jnp.cumsum(totals)[:-1]]
    )
    return (local + carry[:, None]).reshape(-1)[:n]


def finalize_cdf(raw: jax.Array) -> jax.Array:
    """Raw inclusive scan (n,) -> normalized cdf (n+1,) with exact endpoints.

    Shared by the single-device and sharded builders: given bit-equal raw
    scans, it produces bit-equal CDFs (divide/clip are elementwise, the
    monotonicity pass is a ``cummax`` — max is exact, so any execution order
    agrees)."""
    total = raw[-1]
    c = (raw / total).astype(jnp.float32)
    c = jnp.clip(c, 0.0, 1.0).at[-1].set(1.0)
    # Enforce monotonicity under float rounding.
    c = jax.lax.cummax(c)
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), c])


def build_cdf(weights: jax.Array, row_scan=None) -> jax.Array:
    """Normalized inclusive prefix sum with exact 0/1 endpoints.

    Returns ``cdf`` of shape ``(n+1,)`` float32 with cdf[0] == 0, cdf[n] == 1.
    Weights must be non-negative with a positive sum. Ties (zero-probability
    intervals) are permitted; samplers then never return the empty interval
    except on exact boundary hits (measure ~0; see tests).
    """
    w = jnp.asarray(weights, jnp.float32)
    if jax.config.jax_enable_x64:
        # float64 accumulation replaces the chunked grid; the sharded builder
        # refuses this mode (it cannot reproduce it bit-for-bit).
        if row_scan is not None:
            raise ValueError("row_scan is a float32 chunked-scan hook; "
                             "unsupported with jax_enable_x64")
        raw = jnp.cumsum(w.astype(jnp.float64))
    else:
        raw = chunked_cumsum(w, row_scan=row_scan)
    return finalize_cdf(raw)


def cdf_from_logits(logits: jax.Array, temperature: float = 1.0) -> jax.Array:
    """Stable softmax -> CDF along the last axis; shape (..., n) -> (..., n+1)."""
    x = (logits / temperature).astype(jnp.float32)
    x = x - jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    e = jnp.exp(x)
    c = jnp.cumsum(e, axis=-1)
    c = (c / c[..., -1:]).astype(jnp.float32)
    c = jnp.clip(c, 0.0, 1.0)
    c = jax.lax.cummax(c, axis=-1)
    c = c.at[..., -1].set(1.0)
    zero = jnp.zeros(c.shape[:-1] + (1,), jnp.float32)
    return jnp.concatenate([zero, c], axis=-1)


def lower_bounds(cdf: jax.Array) -> jax.Array:
    """Interval lower bounds P_0..P_{n-1} (the radix-tree keys) in [0, 1)."""
    lo = cdf[..., :-1]
    # Keys must live in [0, 1): clamp the (never-sampled) pathological case of
    # an exactly-1.0 lower bound of a zero-width trailing interval.
    return jnp.minimum(lo, _ONE_MINUS_EPS)


def np_build_cdf(weights: np.ndarray) -> np.ndarray:
    """Numpy oracle for tests/benchmarks (float64 accumulate, float32 out)."""
    w = np.asarray(weights, np.float64)
    c = np.cumsum(w)
    c = (c / c[-1]).astype(np.float32)
    c = np.clip(c, 0.0, 1.0)
    c[-1] = 1.0
    c = np.maximum.accumulate(c)
    return np.concatenate([[np.float32(0.0)], c])
