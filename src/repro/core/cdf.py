"""CDF construction: the inversion-method substrate.

``build_cdf`` turns weights into the partition 0 = P_0 < P_1 < ... < P_n = 1
(the paper's Sec. 1). On accelerators this is a parallel prefix sum — the very
operation the paper cites as the cheap, parallel part of inversion-method
setup (in contrast to the serial Alias-Method build). ``cdf_from_logits``
fuses a numerically stable softmax with the scan for LM decode.

The *interval lower bounds* used as radix-tree keys are ``cdf[:-1]``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_ONE_MINUS_EPS = np.float32(np.nextafter(np.float32(1.0), np.float32(0.0)))


def normalize_weights(w: np.ndarray) -> np.ndarray:
    """Float64 normalization for high-dynamic-range weights.

    Distributions like the paper's ``p_i ~ i^20`` overflow float32 *before*
    normalization; normalize in float64 on host first, then feed float32.
    """
    w = np.asarray(w, np.float64)
    s = w.sum()
    if not np.isfinite(s) or s <= 0:
        raise ValueError("weights must be non-negative with a positive finite sum")
    return (w / s).astype(np.float32)


def build_cdf(weights: jax.Array) -> jax.Array:
    """Normalized inclusive prefix sum with exact 0/1 endpoints.

    Returns ``cdf`` of shape ``(n+1,)`` float32 with cdf[0] == 0, cdf[n] == 1.
    Weights must be non-negative with a positive sum. Ties (zero-probability
    intervals) are permitted; samplers then never return the empty interval
    except on exact boundary hits (measure ~0; see tests).
    """
    w = jnp.asarray(weights, jnp.float32)
    c = jnp.cumsum(w.astype(jnp.float64) if jax.config.jax_enable_x64 else w)
    total = c[-1]
    c = (c / total).astype(jnp.float32)
    c = jnp.clip(c, 0.0, 1.0).at[-1].set(1.0)
    # Enforce monotonicity under float rounding.
    c = jax.lax.cummax(c)
    return jnp.concatenate([jnp.zeros((1,), jnp.float32), c])


def cdf_from_logits(logits: jax.Array, temperature: float = 1.0) -> jax.Array:
    """Stable softmax -> CDF along the last axis; shape (..., n) -> (..., n+1)."""
    x = (logits / temperature).astype(jnp.float32)
    x = x - jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    e = jnp.exp(x)
    c = jnp.cumsum(e, axis=-1)
    c = (c / c[..., -1:]).astype(jnp.float32)
    c = jnp.clip(c, 0.0, 1.0)
    c = jax.lax.cummax(c, axis=-1)
    c = c.at[..., -1].set(1.0)
    zero = jnp.zeros(c.shape[:-1] + (1,), jnp.float32)
    return jnp.concatenate([zero, c], axis=-1)


def lower_bounds(cdf: jax.Array) -> jax.Array:
    """Interval lower bounds P_0..P_{n-1} (the radix-tree keys) in [0, 1)."""
    lo = cdf[..., :-1]
    # Keys must live in [0, 1): clamp the (never-sampled) pathological case of
    # an exactly-1.0 lower bound of a zero-width trailing interval.
    return jnp.minimum(lo, _ONE_MINUS_EPS)


def np_build_cdf(weights: np.ndarray) -> np.ndarray:
    """Numpy oracle for tests/benchmarks (float64 accumulate, float32 out)."""
    w = np.asarray(weights, np.float64)
    c = np.cumsum(w)
    c = (c / c[-1]).astype(np.float32)
    c = np.clip(c, 0.0, 1.0)
    c[-1] = 1.0
    c = np.maximum.accumulate(c)
    return np.concatenate([[np.float32(0.0)], c])
