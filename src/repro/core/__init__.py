"""Core library: radix tree forests for parallel discrete sampling."""
from .alias import AliasTable, build_alias, np_sample_alias, sample_alias
from .bits import DIST_SENTINEL, float_to_bits, xor_distance
from .cdf import (
    build_cdf,
    cdf_from_logits,
    lower_bounds,
    normalize_weights,
    np_build_cdf,
)
from .counting import (
    np_sample_binary_counting,
    np_sample_cutpoint_binary_counting,
    np_sample_forest_counting,
    table1_row,
    warp_cost,
)
from .forest import (
    INVALID,
    MAX_DEPTH,
    RadixForest,
    build_forest,
    build_forest_apetrei,
    build_forest_from_cdf,
    depth_stats,
    forest_from_cdf,
    forest_to_numpy,
    validate_forest,
)
from .metrics import (
    chi2_statistic,
    histogram,
    quadratic_error,
    star_discrepancy_1d,
    warped_uniformity_1d,
)
from .sample import (
    sample_binary,
    sample_cutpoint_binary,
    sample_cutpoint_linear,
    sample_forest,
    sample_forest_with_stats,
    sample_linear,
)

__all__ = [k for k in dir() if not k.startswith("_")]
