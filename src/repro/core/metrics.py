"""Uniformity / convergence metrics for the QMC experiments (Figs. 7-9)."""
from __future__ import annotations

import numpy as np


def star_discrepancy_1d(x: np.ndarray) -> float:
    """Exact 1-D star discrepancy in O(N log N) (Niederreiter)."""
    x = np.sort(np.asarray(x, np.float64))
    n = len(x)
    i = np.arange(1, n + 1)
    return float(np.maximum(i / n - x, x - (i - 1) / n).max())


def quadratic_error(counts: np.ndarray, p: np.ndarray) -> float:
    """Fig. 9's metric: sum_i (c_i / N - p_i)^2."""
    c = np.asarray(counts, np.float64)
    n = c.sum()
    return float(np.sum((c / n - np.asarray(p, np.float64)) ** 2))


def histogram(indices: np.ndarray, n: int) -> np.ndarray:
    return np.bincount(np.asarray(indices, np.int64), minlength=n)[:n]


def chi2_statistic(counts: np.ndarray, p: np.ndarray) -> float:
    """Pearson chi^2 against expected N*p (guarded for tiny expectations)."""
    c = np.asarray(counts, np.float64)
    e = np.asarray(p, np.float64) * c.sum()
    mask = e > 1e-12
    return float(np.sum((c[mask] - e[mask]) ** 2 / e[mask]))


def warped_uniformity_1d(xi: np.ndarray, idx: np.ndarray, cdf: np.ndarray) -> float:
    """Star discrepancy of samples *re-flattened* through the true CDF.

    A monotone inverse-CDF warp partitions the input sequence; mapping each
    sample back to (cdf[i] + within-interval offset) must reproduce the input
    uniforms exactly for the inversion method, and scrambles them for the
    Alias Method — this quantifies Fig. 1's 'unwarping' argument.
    """
    xi = np.asarray(xi, np.float64)
    idx = np.asarray(idx, np.int64)
    lo, hi = cdf[idx], cdf[idx + 1]
    width = np.maximum(hi - lo, 1e-30)
    # position within the selected interval, assumed uniform per interval
    frac = np.clip((xi - lo) / width, 0.0, 1.0)
    flattened = lo + frac * width  # == xi for a monotone inverse
    return star_discrepancy_1d(flattened)
