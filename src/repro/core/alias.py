"""The Alias Method (Walker 1974/1977, Vose build) — the paper's antagonist.

O(1) worst-case sampling, but the mapping is **non-monotone** (paper Fig. 6):
warping a low-discrepancy sequence through it destroys uniformity (Figs. 1,
7-9). The build is inherently serial (two work-list passes), in contrast to
the parallel prefix-sum + forest build — the paper's Sec. 2.6 point; we keep
the build in numpy on host and ship the tables to device.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class AliasTable(NamedTuple):
    q: jax.Array      # (n,) f32 split point within each cell
    alias: jax.Array  # (n,) i32 second interval of each cell


def build_alias(weights: np.ndarray) -> AliasTable:
    """Vose's O(n) stable build (serial, as the paper notes)."""
    w = np.asarray(weights, np.float64)
    n = len(w)
    p = w / w.sum() * n
    q = np.ones(n, np.float64)
    alias = np.arange(n, dtype=np.int64)
    small = [i for i in range(n) if p[i] < 1.0]
    large = [i for i in range(n) if p[i] >= 1.0]
    while small and large:
        s, l = small.pop(), large.pop()
        q[s] = p[s]
        alias[s] = l
        p[l] = (p[l] + p[s]) - 1.0
        (small if p[l] < 1.0 else large).append(l)
    for rest in (small, large):
        while rest:
            q[rest.pop()] = 1.0
    return AliasTable(jnp.asarray(q, jnp.float32), jnp.asarray(alias, jnp.int32))


def build_alias_parallel(weights) -> AliasTable:
    """Data-parallel alias construction (beyond-paper: the paper notes that
    known alias builds are serial — this one is prefix sums + two
    searchsorteds, O(n log n) work, O(log n) depth, fully vectorizable).

    Geometric formulation: scale to np_i = n*p_i; lights (np<1) demand
    deficits on a tape (prefix D), heavies supply surpluses (prefix S).
      * light j:  q = np_j, alias = heavy whose supply interval contains the
        START of j's demand interval (D_{j-1});
      * heavy k:  its supply ends at S_k inside some light j(k)'s demand
        interval -> the heavy goes into debt d = D_{j(k)} - S_k, which the
        NEXT heavy covers: q = 1 - d, alias = h_{k+1}; past the last light
        boundary q = 1.
    Validity is a telescoping mass argument (each item ends with exactly
    np_i across its own cell + cells aliasing it), property-tested exactly
    in tests; the pairing differs from Vose's FIFO but any valid table gives
    identical marginals. The mapping remains non-monotone — this accelerates
    the paper's *baseline*, not its monotone sampler.
    """
    w = np.asarray(weights, np.float64)
    n = len(w)
    npi = w / w.sum() * n
    light = npi < 1.0
    lights = np.where(light)[0]
    heavies = np.where(~light)[0]
    q = np.ones(n, np.float64)
    alias = np.arange(n, dtype=np.int64)
    if len(lights) and len(heavies):
        D = np.cumsum(1.0 - npi[lights])          # demand prefix
        S = np.cumsum(npi[heavies] - 1.0)         # supply prefix
        total = min(D[-1], S[-1])                 # equal up to rounding
        # lights: alias = heavy covering the demand start
        starts = np.concatenate([[0.0], D[:-1]])
        k = np.clip(np.searchsorted(S, starts, side="right"), 0, len(heavies) - 1)
        q[lights] = npi[lights]
        alias[lights] = heavies[k]
        # heavies: debt to the next heavy where supply ends mid-demand
        x = S  # supply end per heavy
        j = np.searchsorted(D, x, side="left")    # light whose interval has x
        inside = (j < len(D)) & (x < total)
        Dj = D[np.clip(j, 0, len(D) - 1)]
        debt = np.where(inside, Dj - x, 0.0)
        debt = np.clip(debt, 0.0, 1.0)
        nxt = np.minimum(np.arange(len(heavies)) + 1, len(heavies) - 1)
        q[heavies] = 1.0 - debt
        alias[heavies] = np.where(
            debt > 0, heavies[nxt], heavies
        )
    return AliasTable(jnp.asarray(q, jnp.float32), jnp.asarray(alias, jnp.int32))


def sample_alias(t: AliasTable, xi: jax.Array) -> jax.Array:
    """One load of (q, alias) + one comparison; non-monotone in xi."""
    n = t.q.shape[0]
    scaled = xi * jnp.float32(n)
    cell = jnp.clip(scaled.astype(jnp.int32), 0, n - 1)
    frac = scaled - cell.astype(jnp.float32)
    return jnp.where(frac < t.q[cell], cell, t.alias[cell]).astype(jnp.int32)


def np_sample_alias(q: np.ndarray, alias: np.ndarray, xi: np.ndarray) -> np.ndarray:
    n = len(q)
    scaled = np.asarray(xi, np.float64) * n
    cell = np.clip(scaled.astype(np.int64), 0, n - 1)
    frac = scaled - cell
    return np.where(frac < q[cell], cell, alias[cell])
