"""The Alias Method (Walker 1974/1977, Vose build) — the paper's antagonist,
now also a first-class serving path.

O(1) worst-case sampling, but the mapping is **non-monotone** (paper Fig. 6):
warping a low-discrepancy sequence through it destroys uniformity (Figs. 1,
7-9). That tradeoff is exactly why :class:`repro.pool.ForestPool` carries
*both* methods per tenant: bulk PRNG traffic drains through packed alias
tables at memory speed (Lehmann et al. 2021), while QMC/best-of-n tenants
stay on the monotone radix-forest path. This module holds the
single-distribution host builds and samplers; the batched device-side
split-and-pack construction lives in :mod:`repro.kernels.alias_build` and
the batched drain kernel in :mod:`repro.kernels.alias_sample`.

Sampling edge (the last-cell clamp): a float64 uniform just below 1 rounds
to exactly ``1.0`` when cast to float32 (probability ~2^-25 per draw — a
steady trickle at bulk rates), making ``scaled = xi * n`` land on ``n``;
the clipped cell is ``n-1`` but ``frac = scaled - cell == 1.0``, so the
``frac < q`` comparison failed unconditionally and the draw took
``alias[n-1]`` even when the (float32-cast) table says ``q[n-1] == 1.0``
(all mass in the cell itself). ``frac`` is therefore clamped into
``[0, 1)``: the limit draw behaves as ``xi -> 1^-`` at table resolution.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Largest float32 / float64 strictly below 1: the upper clamp for the
# within-cell fraction, so `frac < q` stays meaningful for q == 1 cells.
ALIAS_FRAC_MAX = np.float32(np.nextafter(np.float32(1.0), np.float32(0.0)))
_ALIAS_FRAC_MAX64 = np.nextafter(1.0, 0.0)


class AliasTable(NamedTuple):
    q: jax.Array      # (n,) f32 split point within each cell
    alias: jax.Array  # (n,) i32 second interval of each cell


def build_alias(weights: np.ndarray) -> AliasTable:
    """Vose's O(n) stable build (serial, as the paper notes)."""
    w = np.asarray(weights, np.float64)
    n = len(w)
    p = w / w.sum() * n
    q = np.ones(n, np.float64)
    alias = np.arange(n, dtype=np.int64)
    small = [i for i in range(n) if p[i] < 1.0]
    large = [i for i in range(n) if p[i] >= 1.0]
    while small and large:
        s, l = small.pop(), large.pop()
        q[s] = p[s]
        alias[s] = l
        p[l] = (p[l] + p[s]) - 1.0
        (small if p[l] < 1.0 else large).append(l)
    for rest in (small, large):
        while rest:
            q[rest.pop()] = 1.0
    return AliasTable(jnp.asarray(q, jnp.float32), jnp.asarray(alias, jnp.int32))


def build_alias_parallel(weights) -> AliasTable:
    """Data-parallel alias construction (beyond-paper: the paper notes that
    known alias builds are serial — this one is prefix sums + searchsorteds,
    O(n log n) work, O(log n) depth, fully vectorizable).

    Geometric formulation: scale to np_i = n*p_i; lights (np<1) demand
    deficits on a tape (prefix D), heavies supply surpluses (prefix S).
      * light j:  q = np_j, alias = heavy whose supply interval contains the
        START of j's demand interval (D_{j-1});
      * heavy k:  its supply ends at S_k inside some light j(k)'s demand
        interval -> the heavy goes into debt d = D_{j(k)} - S_k, which the
        next heavy *with remaining surplus* covers: q = 1 - d, alias = that
        heavy; past the last light boundary q = 1.
    Boundary handling matters with exact (dyadic) weights: a heavy with
    np_k == 1 supplies a zero-width interval, so its supply "end" can land
    exactly on a demand boundary without the heavy having covered anything —
    such heavies owe no debt (``surplus > 0`` gates the debt), and a real
    debt is routed past any zero-surplus run to the first heavy whose prefix
    strictly exceeds S_k (``searchsorted(S, S_k, side="right")``, the same
    rule the lights use, rather than the positional ``k+1``).
    Validity is a telescoping mass argument (each item ends with exactly
    np_i across its own cell + cells aliasing it), property-tested exactly
    in tests; the pairing differs from Vose's FIFO but any valid table gives
    identical marginals. The mapping remains non-monotone — this accelerates
    the paper's *baseline*, not its monotone sampler.
    """
    w = np.asarray(weights, np.float64)
    n = len(w)
    npi = w / w.sum() * n
    light = npi < 1.0
    lights = np.where(light)[0]
    heavies = np.where(~light)[0]
    q = np.ones(n, np.float64)
    alias = np.arange(n, dtype=np.int64)
    if len(lights) and len(heavies):
        D = np.cumsum(1.0 - npi[lights])          # demand prefix
        S = np.cumsum(npi[heavies] - 1.0)         # supply prefix
        total = min(D[-1], S[-1])                 # equal up to rounding
        # lights: alias = heavy covering the demand start (side="right"
        # skips every heavy whose supply is exhausted at the boundary,
        # including zero-surplus heavies whose interval is empty)
        starts = np.concatenate([[0.0], D[:-1]])
        k = np.clip(np.searchsorted(S, starts, side="right"), 0, len(heavies) - 1)
        q[lights] = npi[lights]
        alias[lights] = heavies[k]
        # heavies: debt to the next supplying heavy where supply ends
        # mid-demand; zero-surplus heavies (np_k == 1 exactly) supplied
        # nothing, so a boundary coincidence must not charge them
        surplus = npi[heavies] - 1.0
        x = S  # supply end per heavy
        j = np.searchsorted(D, x, side="left")    # light whose interval has x
        inside = (j < len(D)) & (x < total) & (surplus > 0.0)
        Dj = D[np.clip(j, 0, len(D) - 1)]
        debt = np.where(inside, Dj - x, 0.0)
        debt = np.clip(debt, 0.0, 1.0)
        # the covering heavy is the first with prefix strictly past S_k —
        # positional k+1 would hand the debt to a zero-surplus heavy
        nxt = np.clip(np.searchsorted(S, x, side="right"), 0, len(heavies) - 1)
        q[heavies] = 1.0 - debt
        alias[heavies] = np.where(
            debt > 0, heavies[nxt], heavies
        )
    return AliasTable(jnp.asarray(q, jnp.float32), jnp.asarray(alias, jnp.int32))


def sample_alias(t: AliasTable, xi: jax.Array) -> jax.Array:
    """One load of (q, alias) + one comparison; non-monotone in xi.

    ``frac`` is clamped into [0, 1): ``xi == 1.0`` (a float64 uniform just
    below 1, rounded up by the f32 cast) must behave as the limit draw
    ``xi -> 1^-`` — without the clamp ``frac == 1.0 >= q`` took the alias
    unconditionally, even in cells whose table says q == 1."""
    n = t.q.shape[0]
    scaled = xi * jnp.float32(n)
    cell = jnp.clip(scaled.astype(jnp.int32), 0, n - 1)
    frac = jnp.clip(scaled - cell.astype(jnp.float32), 0.0, ALIAS_FRAC_MAX)
    return jnp.where(frac < t.q[cell], cell, t.alias[cell]).astype(jnp.int32)


def np_sample_alias(q: np.ndarray, alias: np.ndarray, xi: np.ndarray) -> np.ndarray:
    """Host twin of :func:`sample_alias` in float64 (the bench baseline).

    Same last-cell clamp: the int64 truncation of ``scaled`` is exact for
    any realistic n, but ``xi == 1.0`` still lands ``scaled`` on ``n`` and
    the clipped cell would see ``frac == 1.0``."""
    n = len(q)
    scaled = np.asarray(xi, np.float64) * n
    cell = np.clip(scaled.astype(np.int64), 0, n - 1)
    frac = np.clip(scaled - cell, 0.0, _ALIAS_FRAC_MAX64)
    return np.where(frac < q[cell], cell, alias[cell])


def np_sample_alias_f32(q: np.ndarray, alias: np.ndarray,
                        xi: np.ndarray) -> np.ndarray:
    """Numpy oracle mirroring the device drain's float32 arithmetic exactly
    (same multiply, truncation, and clamp — IEEE f32 on both sides), so the
    batched alias kernel can be asserted **elementwise** against it."""
    n = len(q)
    scaled = np.asarray(xi, np.float32) * np.float32(n)
    cell = np.clip(scaled.astype(np.int32), 0, n - 1)
    frac = np.clip(scaled - cell.astype(np.float32),
                   np.float32(0.0), ALIAS_FRAC_MAX)
    return np.where(frac < np.asarray(q, np.float32)[cell],
                    cell, alias[cell]).astype(np.int32)
