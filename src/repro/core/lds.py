"""Low-discrepancy sequence generators (Hammersley, Halton, Sobol', van der Corput).

Used to reproduce the paper's QMC experiments (Figs. 1, 7, 8, 9): warping a
low-discrepancy sequence through the *monotone* inverse CDF preserves
uniformity properties in warped space; warping through the Alias Method does
not. Also used by the serving layer for per-slot QMC token-sampling streams.

The serving streams run in 24-bit fixed point (:func:`qmc_bits24_np` /
:func:`qmc_bits24`): counter -> bit-reversed 24-bit radical inverse ->
Cranley-Patterson rotation as an *integer* add mod 2^24 -> exact float32.
Every step is exact integer arithmetic plus one exact int->float conversion,
so the host oracle (numpy), the jnp device twin, and the Pallas drain kernel
produce bit-identical points by construction — no float-rounding argument
required.
"""
from __future__ import annotations

import numpy as np

_PRIMES = np.array(
    [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53], np.int64
)

# Sobol' direction numbers (Joe & Kuo, new-joe-kuo-6) for dimensions 1..16.
# Dim 0 is van der Corput in base 2. Entries: (s, a, m_i ...). Indexing is
# strict: asking for a dimension past the table raises instead of silently
# recycling a polynomial (recycling makes the recycled pair's columns
# *identical*, degenerating every 2D projection that spans them).
_SOBOL_POLY = [
    (1, 0, [1]),
    (2, 1, [1, 3]),
    (3, 1, [1, 3, 1]),
    (3, 2, [1, 1, 1]),
    (4, 1, [1, 1, 3, 3]),
    (4, 4, [1, 3, 5, 13]),
    (5, 2, [1, 1, 5, 5, 17]),
    (5, 4, [1, 1, 5, 5, 5]),
    (5, 7, [1, 1, 7, 11, 19]),
    (5, 11, [1, 1, 5, 1, 1]),
    (5, 13, [1, 1, 1, 3, 11]),
    (5, 14, [1, 3, 5, 5, 31]),
    (6, 1, [1, 3, 3, 9, 7, 49]),
    (6, 13, [1, 1, 1, 15, 21, 21]),
    (6, 16, [1, 3, 1, 13, 27, 49]),
    (6, 19, [1, 1, 1, 15, 7, 5]),
]

SOBOL_MAX_DIMS = len(_SOBOL_POLY) + 1  # + dim 0 (van der Corput)

QMC_BITS = 24                  # fixed-point resolution of the stream points
QMC_SCALE = np.float32(2.0 ** -QMC_BITS)
_QMC_MASK = np.uint32((1 << QMC_BITS) - 1)


def reverse_bits32_np(i: np.ndarray) -> np.ndarray:
    """Bit-reverse uint32 values (numpy)."""
    b = np.asarray(i, np.uint32).copy()
    b = ((b & np.uint32(0x55555555)) << np.uint32(1)) | ((b & np.uint32(0xAAAAAAAA)) >> np.uint32(1))
    b = ((b & np.uint32(0x33333333)) << np.uint32(2)) | ((b & np.uint32(0xCCCCCCCC)) >> np.uint32(2))
    b = ((b & np.uint32(0x0F0F0F0F)) << np.uint32(4)) | ((b & np.uint32(0xF0F0F0F0)) >> np.uint32(4))
    b = ((b & np.uint32(0x00FF00FF)) << np.uint32(8)) | ((b & np.uint32(0xFF00FF00)) >> np.uint32(8))
    return (b << np.uint32(16)) | (b >> np.uint32(16))


def qmc_bits24_np(counter: np.ndarray, offset_bits: np.ndarray) -> np.ndarray:
    """Counter -> rotated 24-bit stream point (integer form, numpy host side).

    ``reverse_bits32 >> 8`` is the base-2 radical inverse in units of 2^-24;
    the Cranley-Patterson rotation is an integer add mod 2^24, so the whole
    pipeline is exact and bit-identical to the jnp/Pallas twins."""
    rev = reverse_bits32_np(counter) >> np.uint32(32 - QMC_BITS)
    return (rev + np.asarray(offset_bits, np.uint32)) & _QMC_MASK


def qmc_point_np(counter: np.ndarray, offset_bits: np.ndarray) -> np.ndarray:
    """Rotated stream point as exact float32 in [0, 1)."""
    return qmc_bits24_np(counter, offset_bits).astype(np.float32) * QMC_SCALE


def qmc_offset_bits_np(offsets01) -> np.ndarray:
    """Quantize [0,1) rotation offsets to the stream's 24-bit grid."""
    bits = (np.asarray(offsets01, np.float64) * (1 << QMC_BITS)).astype(np.uint32)
    return np.minimum(bits, _QMC_MASK)


def reverse_bits32(i):
    """Bit-reverse uint32 values (jnp twin of :func:`reverse_bits32_np`;
    also safe inside Pallas kernel bodies — shifts/masks only)."""
    import jax.numpy as jnp  # local: keep numpy-only callers jax-free

    b = jnp.asarray(i, jnp.uint32)
    b = ((b & jnp.uint32(0x55555555)) << 1) | ((b & jnp.uint32(0xAAAAAAAA)) >> 1)
    b = ((b & jnp.uint32(0x33333333)) << 2) | ((b & jnp.uint32(0xCCCCCCCC)) >> 2)
    b = ((b & jnp.uint32(0x0F0F0F0F)) << 4) | ((b & jnp.uint32(0xF0F0F0F0)) >> 4)
    b = ((b & jnp.uint32(0x00FF00FF)) << 8) | ((b & jnp.uint32(0xFF00FF00)) >> 8)
    return (b << 16) | (b >> 16)


def qmc_bits24(counter, offset_bits):
    """jnp twin of :func:`qmc_bits24_np` (identical integer pipeline)."""
    import jax.numpy as jnp

    rev = reverse_bits32(counter) >> (32 - QMC_BITS)
    return (rev + jnp.asarray(offset_bits, jnp.uint32)) & jnp.uint32(_QMC_MASK)


def qmc_point(counter, offset_bits):
    """jnp twin of :func:`qmc_point_np` (exact float32 in [0, 1))."""
    import jax.numpy as jnp

    return qmc_bits24(counter, offset_bits).astype(jnp.float32) * QMC_SCALE


def _sobol2_v24() -> np.ndarray:
    """Sobol' dimension-1 direction numbers on the 24-bit stream grid."""
    return (_sobol_directions(1) >> np.uint64(32 - QMC_BITS)).astype(np.uint32)


def sobol2_bits24_np(counter: np.ndarray) -> np.ndarray:
    """Counter -> unrotated Sobol' dim-1 point in units of 2^-24 (numpy).

    Direct binary indexing (XOR of direction numbers for set counter bits);
    the pipeline is pure integer XOR/shift so the jnp twin
    (:func:`sobol2_bits24`) is bit-identical by construction. Together with
    the van der Corput u-dimension of :func:`qmc_bits24_np` (= Sobol' dim 0)
    this forms the exact 2-D Sobol' pair used by the spatial serving
    streams."""
    c = np.asarray(counter, np.uint32)
    v = _sobol2_v24()
    x = np.zeros(c.shape, np.uint32)
    for k in range(32):
        bit = (c >> np.uint32(k)) & np.uint32(1)
        x ^= bit * v[k]
    return x & _QMC_MASK


def qmc2_bits24_np(
    counter: np.ndarray, offset_u: np.ndarray, offset_v: np.ndarray
):
    """Counter -> rotated 2-D stream point (integer form, numpy host side).

    u is the base-2 radical inverse, v is Sobol' dim 1; each carries its own
    Cranley-Patterson rotation as an integer add mod 2^24, so host, jnp and
    kernel twins agree bit-for-bit."""
    u = qmc_bits24_np(counter, offset_u)
    v = (sobol2_bits24_np(counter) + np.asarray(offset_v, np.uint32)) & _QMC_MASK
    return u, v


def qmc2_point_np(
    counter: np.ndarray, offset_u: np.ndarray, offset_v: np.ndarray
):
    """Rotated 2-D stream point as exact float32 pairs in [0, 1)^2."""
    u, v = qmc2_bits24_np(counter, offset_u, offset_v)
    return u.astype(np.float32) * QMC_SCALE, v.astype(np.float32) * QMC_SCALE


def sobol2_bits24(counter):
    """jnp twin of :func:`sobol2_bits24_np` (identical integer pipeline)."""
    import jax.numpy as jnp

    c = jnp.asarray(counter, jnp.uint32)
    v = _sobol2_v24()
    x = jnp.zeros_like(c)
    for k in range(32):
        bit = (c >> jnp.uint32(k)) & jnp.uint32(1)
        x = x ^ bit * jnp.uint32(int(v[k]))
    return x & jnp.uint32(_QMC_MASK)


def qmc2_bits24(counter, offset_u, offset_v):
    """jnp twin of :func:`qmc2_bits24_np`."""
    import jax.numpy as jnp

    u = qmc_bits24(counter, offset_u)
    v = (sobol2_bits24(counter) + jnp.asarray(offset_v, jnp.uint32)) & jnp.uint32(
        _QMC_MASK
    )
    return u, v


def qmc2_point(counter, offset_u, offset_v):
    """jnp twin of :func:`qmc2_point_np` (exact float32 in [0, 1)^2)."""
    import jax.numpy as jnp

    u, v = qmc2_bits24(counter, offset_u, offset_v)
    return u.astype(jnp.float32) * QMC_SCALE, v.astype(jnp.float32) * QMC_SCALE


def radical_inverse_base2(i: np.ndarray) -> np.ndarray:
    """Van der Corput sequence in base 2 via 32-bit reversal (float32 exact)."""
    b = reverse_bits32_np(np.asarray(i, np.uint32))
    return (b >> np.uint32(8)).astype(np.float64) * (1.0 / (1 << 24))


def radical_inverse(i: np.ndarray, base: int) -> np.ndarray:
    """Van der Corput sequence in arbitrary integer base."""
    if base == 2:
        return radical_inverse_base2(i)
    i = np.asarray(i, np.int64).copy()
    inv = np.zeros(i.shape, np.float64)
    f = 1.0 / base
    while np.any(i > 0):
        inv += f * (i % base)
        i //= base
        f /= base
    return inv


def hammersley(n: int, dims: int = 2) -> np.ndarray:
    """The n-point Hammersley set in [0,1)^dims (first component = i/n)."""
    idx = np.arange(n, dtype=np.int64)
    cols = [idx.astype(np.float64) / n]
    for d in range(dims - 1):
        cols.append(radical_inverse(idx, int(_PRIMES[d])))
    return np.stack(cols, axis=-1)


def halton(n: int, dims: int = 2, start: int = 0) -> np.ndarray:
    idx = np.arange(start, start + n, dtype=np.int64)
    cols = [radical_inverse(idx, int(_PRIMES[d])) for d in range(dims)]
    return np.stack(cols, axis=-1)


def _sobol_directions(dim: int, bits: int = 32) -> np.ndarray:
    """Direction numbers v_k (as uint32 scaled by 2^32) for one dimension."""
    if dim == 0:
        return np.array([1 << (31 - k) for k in range(bits)], np.uint64)
    if dim - 1 >= len(_SOBOL_POLY):
        raise ValueError(
            f"sobol direction-number table covers dims <= {SOBOL_MAX_DIMS} "
            f"(got dimension index {dim}); recycling polynomials would make "
            f"dimensions {dim} and {((dim - 1) % len(_SOBOL_POLY)) + 1} "
            "identical — extend _SOBOL_POLY (Joe & Kuo) instead"
        )
    s, a, m = _SOBOL_POLY[dim - 1]
    m = list(m)
    v = np.zeros(bits, np.uint64)
    for k in range(s):
        v[k] = np.uint64(m[k]) << np.uint64(31 - k)
    for k in range(s, bits):
        vk = v[k - s] ^ (v[k - s] >> np.uint64(s))
        for j in range(1, s):
            if (a >> (s - 1 - j)) & 1:
                vk ^= v[k - j]
        v[k] = vk
    return v


def sobol(n: int, dims: int = 2, scramble_seed: int | None = None) -> np.ndarray:
    """First n points of the Sobol' sequence (graycode order), optional
    Owen-style digital shift (XOR scramble) per dimension. Supports up to
    ``SOBOL_MAX_DIMS`` dimensions; beyond that the direction-number table
    raises (recycled polynomials would duplicate columns)."""
    out = np.zeros((n, dims), np.float64)
    rng = np.random.default_rng(scramble_seed) if scramble_seed is not None else None
    idx = np.arange(n, dtype=np.uint64)
    gray = idx ^ (idx >> np.uint64(1))
    for d in range(dims):
        v = _sobol_directions(d)
        x = np.zeros(n, np.uint64)
        g = gray.copy()
        for k in range(32):
            bit = (g >> np.uint64(k)) & np.uint64(1)
            x ^= bit * v[k]
        if rng is not None:
            x ^= np.uint64(rng.integers(0, 1 << 32, dtype=np.uint64))
        out[:, d] = (x >> np.uint64(8)).astype(np.float64) * (1.0 / (1 << 24))
    return out


def uniform(n: int, dims: int = 2, seed: int = 0) -> np.ndarray:
    """Plain pseudo-random points — the MC baseline for QMC comparisons."""
    return np.random.default_rng(seed).random((n, dims))
