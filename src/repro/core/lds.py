"""Low-discrepancy sequence generators (Hammersley, Halton, Sobol', van der Corput).

Used to reproduce the paper's QMC experiments (Figs. 1, 7, 8, 9): warping a
low-discrepancy sequence through the *monotone* inverse CDF preserves
uniformity properties in warped space; warping through the Alias Method does
not. Also used by the serving layer for per-slot QMC token-sampling streams.
"""
from __future__ import annotations

import numpy as np

_PRIMES = np.array(
    [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53], np.int64
)

# Sobol' direction numbers (Joe & Kuo style) for the first 8 dimensions.
# Dim 0 is van der Corput in base 2. Entries: (s, a, m_i ...).
_SOBOL_POLY = [
    (1, 0, [1]),
    (2, 1, [1, 3]),
    (3, 1, [1, 3, 1]),
    (3, 2, [1, 1, 1]),
    (4, 1, [1, 1, 3, 3]),
    (4, 4, [1, 3, 5, 13]),
    (5, 2, [1, 1, 5, 5, 17]),
]


def radical_inverse_base2(i: np.ndarray) -> np.ndarray:
    """Van der Corput sequence in base 2 via 32-bit reversal (float32 exact)."""
    i = np.asarray(i, np.uint32)
    b = i.copy()
    b = ((b & np.uint32(0x55555555)) << np.uint32(1)) | ((b & np.uint32(0xAAAAAAAA)) >> np.uint32(1))
    b = ((b & np.uint32(0x33333333)) << np.uint32(2)) | ((b & np.uint32(0xCCCCCCCC)) >> np.uint32(2))
    b = ((b & np.uint32(0x0F0F0F0F)) << np.uint32(4)) | ((b & np.uint32(0xF0F0F0F0)) >> np.uint32(4))
    b = ((b & np.uint32(0x00FF00FF)) << np.uint32(8)) | ((b & np.uint32(0xFF00FF00)) >> np.uint32(8))
    b = (b << np.uint32(16)) | (b >> np.uint32(16))
    return (b >> np.uint32(8)).astype(np.float64) * (1.0 / (1 << 24))


def radical_inverse(i: np.ndarray, base: int) -> np.ndarray:
    """Van der Corput sequence in arbitrary integer base."""
    if base == 2:
        return radical_inverse_base2(i)
    i = np.asarray(i, np.int64).copy()
    inv = np.zeros(i.shape, np.float64)
    f = 1.0 / base
    while np.any(i > 0):
        inv += f * (i % base)
        i //= base
        f /= base
    return inv


def hammersley(n: int, dims: int = 2) -> np.ndarray:
    """The n-point Hammersley set in [0,1)^dims (first component = i/n)."""
    idx = np.arange(n, dtype=np.int64)
    cols = [idx.astype(np.float64) / n]
    for d in range(dims - 1):
        cols.append(radical_inverse(idx, int(_PRIMES[d])))
    return np.stack(cols, axis=-1)


def halton(n: int, dims: int = 2, start: int = 0) -> np.ndarray:
    idx = np.arange(start, start + n, dtype=np.int64)
    cols = [radical_inverse(idx, int(_PRIMES[d])) for d in range(dims)]
    return np.stack(cols, axis=-1)


def _sobol_directions(dim: int, bits: int = 32) -> np.ndarray:
    """Direction numbers v_k (as uint32 scaled by 2^32) for one dimension."""
    if dim == 0:
        return np.array([1 << (31 - k) for k in range(bits)], np.uint64)
    s, a, m = _SOBOL_POLY[(dim - 1) % len(_SOBOL_POLY)]
    m = list(m)
    v = np.zeros(bits, np.uint64)
    for k in range(s):
        v[k] = np.uint64(m[k]) << np.uint64(31 - k)
    for k in range(s, bits):
        vk = v[k - s] ^ (v[k - s] >> np.uint64(s))
        for j in range(1, s):
            if (a >> (s - 1 - j)) & 1:
                vk ^= v[k - j]
        v[k] = vk
    return v


def sobol(n: int, dims: int = 2, scramble_seed: int | None = None) -> np.ndarray:
    """First n points of the Sobol' sequence (graycode order), optional
    Owen-style digital shift (XOR scramble) per dimension."""
    out = np.zeros((n, dims), np.float64)
    rng = np.random.default_rng(scramble_seed) if scramble_seed is not None else None
    idx = np.arange(n, dtype=np.uint64)
    gray = idx ^ (idx >> np.uint64(1))
    for d in range(dims):
        v = _sobol_directions(d)
        x = np.zeros(n, np.uint64)
        g = gray.copy()
        for k in range(32):
            bit = (g >> np.uint64(k)) & np.uint64(1)
            x ^= bit * v[k]
        if rng is not None:
            x ^= np.uint64(rng.integers(0, 1 << 32, dtype=np.uint64))
        out[:, d] = (x >> np.uint64(8)).astype(np.float64) * (1.0 / (1 << 24))
    return out


def uniform(n: int, dims: int = 2, seed: int = 0) -> np.ndarray:
    """Plain pseudo-random points — the MC baseline for QMC comparisons."""
    return np.random.default_rng(seed).random((n, dims))
