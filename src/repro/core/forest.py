"""Radix tree forests over CDF intervals (Binder & Keller 2019, Sec. 3).

The structure: the unit interval is cut into ``m`` guide cells. A cell
overlapped by a single CDF interval stores ``~i`` (two's complement, MSB set)
directly in the guide table. A cell containing several interval lower bounds
stores the index of its *root slot* node; the per-cell radix tree over the
contained lower bounds hangs off that slot's right child, while the slot's
left child is manually set to the interval overlapping the cell from the left
(paper Fig. 11). Node index ``j`` doubles as CDF index: node ``j`` splits at
``cdf[j]`` (the Apetrei enumeration), so nodes store only two child refs.

Child references: ``>= 0`` → internal node id, ``< 0`` → leaf ``~i``.

Slot accounting (a property worth stating): with ``n`` intervals there are
exactly ``n`` node slots and all are used — ``n-1-#crossing`` internal
separators (separator ``k`` ↔ node ``k+1``) plus ``#crossing+1`` cell root
slots (the first leaf index of each non-empty cell; the crossing separator's
own node id *is* the next cell's root slot). Indices of nodes of small
subtrees are consecutive, which the paper exploits for cache locality.

Two builders produce bit-identical forests:

* :func:`build_forest` — TPU-native: the radix forest is the Cartesian
  (max-)tree over separator distances ``delta(k) = bits(data[k]) XOR
  bits(data[k+1])`` with cell-crossing separators clamped to the sentinel
  distance. Parents are found in closed form with an all-nearest-greater-
  values sparse-table descent: O(n log n) work, O(log n) depth, **no
  atomics**, perfectly load-balanced (identical instruction stream per lane).
* :func:`build_forest_apetrei` — a round-synchronous faithful emulation of
  the paper's Algorithm 1 (bottom-up merging with atomicExch emulation),
  kept as ground truth for tests and as executable documentation.

Tie-breaking matches Algorithm 1: a subtree whose left/right boundary
distances are equal merges left (becomes the *right* child of the node at its
low bound). In nearest-greater terms: L(k) uses strict ``>``, R(k) uses
``>=``, and the parent is L when ``delta[L] <= delta[R]``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .bits import DIST_SENTINEL, float_to_bits, np_xor_distance
from .cdf import build_cdf, lower_bounds, np_build_cdf

INVALID = np.int32(-(2**31))  # never a legal ref; only in untouched slots
# Radix-tree depth over *distinct* float32 keys is <= ~34 (one bit level per
# edge). Zero-width intervals (tied CDF values, delta == 0) chain arbitrarily
# deep; such cells are flagged for balanced fallback at build time, so 256 is
# a pure safety guard for fallback-disabled traversal.
MAX_DEPTH = 256
_DEPTH_ITERS = 48  # saturating depth count; anything deeper is flagged anyway


class RadixForest(NamedTuple):
    """Guide table + radix tree forest (+ cutpoint/fallback side tables)."""

    cdf: jax.Array         # (n+1,) f32; interval i = [cdf[i], cdf[i+1])
    table: jax.Array       # (m,)  i32; >=0 node id, <0 ~interval
    left: jax.Array        # (n,)  i32 child refs
    right: jax.Array       # (n,)  i32 child refs
    cell_first: jax.Array  # (m+1,) i32 first interval overlapping each cell
    fallback: jax.Array    # (m,)  bool; degenerate cell -> balanced bisection

    @property
    def n(self) -> int:
        return self.left.shape[0]

    @property
    def m(self) -> int:
        return self.table.shape[0]


def _cells(data: jax.Array, m: int) -> jax.Array:
    """Guide cell of each lower bound; float32 math to match traversal."""
    c = jnp.floor(data * jnp.float32(m)).astype(jnp.int32)
    return jnp.clip(c, 0, m - 1)


def _block_max_table(d: jax.Array, levels: int) -> list[jax.Array]:
    """T[j][s] = max d[s : s+2^j] (out of range = 0, neutral for uint)."""
    tables = [d]
    cur = d
    for j in range(levels):
        shift = 1 << j
        shifted = jnp.concatenate(
            [cur[shift:], jnp.zeros((min(shift, cur.shape[0]),), cur.dtype)]
        )[: cur.shape[0]]
        cur = jnp.maximum(cur, shifted)
        tables.append(cur)
    return tables


def _nearest_greater(d: jax.Array):
    """For every separator k return (dL, L, dR, R):

    L(k): nearest l < k with d[l] >  d[k]  (virtual boundary -1, SENTINEL)
    R(k): nearest r > k with d[r] >= d[k]  (virtual boundary len, SENTINEL)
    """
    s = d.shape[0]
    levels = max(1, int(np.ceil(np.log2(max(s, 2)))))
    T = _block_max_table(d, levels)
    k = jnp.arange(s, dtype=jnp.int32)
    v = d

    # Left search: shrink exclusive upper bound p while block has no '> v'.
    p = k
    for j in range(levels, -1, -1):
        step = 1 << j
        idx = jnp.clip(p - step, 0, max(s - 1, 0))
        can = (p >= step) & (T[min(j, len(T) - 1)][idx] <= v)
        p = jnp.where(can, p - step, p)
    L = p - 1
    dL = jnp.where(L >= 0, d[jnp.clip(L, 0)], jnp.uint32(DIST_SENTINEL))

    # Right search: grow start q while block has no '>= v'.
    q = k + 1
    for j in range(levels, -1, -1):
        step = 1 << j
        idx = jnp.clip(q, 0, max(s - 1, 0))
        can = (q + step <= s) & (T[min(j, len(T) - 1)][idx] < v)
        q = jnp.where(can, q + step, q)
    R = q
    dR = jnp.where(R < s, d[jnp.clip(R, 0, max(s - 1, 0))], jnp.uint32(DIST_SENTINEL))
    return dL, L, dR, R


def _separator_distances(data: jax.Array, cells: jax.Array) -> jax.Array:
    """(n-1,) XOR separator distances; cell crossings clamp to the sentinel."""
    bits = float_to_bits(data)
    sep_raw = bits[:-1] ^ bits[1:]
    crossing = cells[:-1] != cells[1:]
    return jnp.where(crossing, jnp.uint32(DIST_SENTINEL), sep_raw)


def _build_cell_trees(
    data: jax.Array,
    d: jax.Array,
    cells: jax.Array,
    *,
    m: int,
    cell_lo,
    m_local: int,
    m_owned=None,
    node_offset=0,
    n_total: int | None = None,
    fallback_slack: int = 2,
):
    """Per-cell radix trees for the guide-cell range [cell_lo, cell_lo+m_owned).

    The shared build core of the single-device path (``cell_lo=0,
    m_local=m``) and the cell-partitioned sharded path
    (:mod:`repro.dist.forest`). ``data``/``cells``/``d`` are a contiguous
    window of the global leaf arrays; window index ``w`` is global leaf
    ``w + node_offset``, and all *stored references* (node ids, leaf refs,
    ``table``/``cell_first`` entries) are global. ``cell_lo`` and
    ``node_offset`` may be traced (they come from per-shard plan arrays
    indexed by ``axis_index`` under ``shard_map``); ``m_local`` is static.

    ``m_owned`` (traced, default ``m_local``) is the number of *owned* cells
    at the front of the ``m_local``-sized cell window. Shard plans with
    unequal cell ranges pad every range to a static capacity ``m_local``;
    the ``[m_owned, m_local)`` slack carries no ownership, so its per-cell
    outputs (``table``/``cell_first``/``fallback`` rows) are garbage the
    caller must mask out.

    Every edge of a cell's tree stays inside that cell (crossing separators
    carry the sentinel distance), so a node slot is written only by the cell
    owning its leaf. Restricting writes to an ownership mask therefore makes
    partial results from a *disjoint* cell partition combine exactly by
    elementwise max (``INVALID`` is int32 min): the combination of the shards
    is bit-identical to the unpartitioned build.

    Returns ``(left, right, table, cell_first, fallback)``: window-sized
    ``left``/``right`` (unowned slots ``INVALID``) and ``(m_local,)`` per-cell
    arrays for the owned range.
    """
    n = data.shape[0]
    n_total = n if n_total is None else n_total
    sentinel = jnp.uint32(DIST_SENTINEL)
    cell_lo = jnp.int32(cell_lo)
    node_offset = jnp.int32(node_offset)
    m_owned = jnp.int32(m_local if m_owned is None else m_owned)

    # Ownership; out-of-range scatter indices route to m_local and drop
    # (negative indices would wrap, so they must be rewritten, not dropped).
    loc = cells - cell_lo
    owned_leaf = (loc >= 0) & (loc < m_owned)
    loc_safe = jnp.where(owned_leaf, loc, m_local)

    grid = (cell_lo + jnp.arange(m_local, dtype=jnp.int32)).astype(
        jnp.float32
    ) / jnp.float32(m)
    cell_first = (
        jnp.searchsorted(data, grid, side="right").astype(jnp.int32) - 1
    )
    cell_first = jnp.clip(cell_first + node_offset, 0, n_total - 1)

    counts = jnp.zeros((m_local,), jnp.int32).at[loc_safe].add(1, mode="drop")
    first_leaf = jnp.full((m_local,), n, jnp.int32).at[loc_safe].min(
        jnp.arange(n, dtype=jnp.int32), mode="drop"
    )
    f_safe = jnp.clip(first_leaf, 0, n - 1)       # window-relative
    left_overlap = data[f_safe] > grid
    overlap = jnp.where(counts > 0, counts + left_overlap.astype(jnp.int32), 1)

    left = jnp.full((n,), INVALID, jnp.int32)
    right = jnp.full((n,), INVALID, jnp.int32)
    leaf_parent = jnp.full((n,), -1, jnp.int32)   # window-relative node ids
    node_parent = jnp.full((n,), -1, jnp.int32)

    if n > 1:
        dL, _L, dR, _R = _nearest_greater(d)
        k = jnp.arange(n - 1, dtype=jnp.int32)
        in_cell = d != sentinel
        owned_k = owned_leaf[:-1]    # separator k lives in cell cells[k]
        is_root = in_cell & (dL == sentinel) & (dR == sentinel)
        par_is_L = dL <= dR
        parent_sep = jnp.where(par_is_L, _L, _R)
        parent_node = parent_sep + 1              # window-relative slot
        node_id = k + 1 + node_offset             # global reference value

        # Internal non-root separators -> child of parent separator's node.
        wr = owned_k & in_cell & ~is_root & par_is_L    # right child of L
        wl = owned_k & in_cell & ~is_root & ~par_is_L   # left child of R
        right = right.at[jnp.where(wr, parent_node, n)].set(node_id, mode="drop")
        left = left.at[jnp.where(wl, parent_node, n)].set(node_id, mode="drop")
        node_parent = node_parent.at[
            jnp.where(owned_k & in_cell & ~is_root, k + 1, n)
        ].set(parent_node, mode="drop")

        # Cell roots -> right child of the cell's root slot.
        root_slot = first_leaf[
            jnp.clip(loc[jnp.clip(k, 0, n - 1)], 0, m_local - 1)
        ]
        wroot = owned_k & is_root
        right = right.at[jnp.where(wroot, root_slot, n)].set(node_id, mode="drop")
        node_parent = node_parent.at[jnp.where(wroot, k + 1, n)].set(
            root_slot, mode="drop"
        )

    # Leaves.
    i = jnp.arange(n, dtype=jnp.int32)
    dl = jnp.where(i > 0, d[jnp.clip(i - 1, 0)], sentinel) if n > 1 else jnp.full(
        (n,), sentinel, jnp.uint32
    )
    dr = jnp.where(i < n - 1, d[jnp.clip(i, 0, max(n - 2, 0))], sentinel) if n > 1 else (
        jnp.full((n,), sentinel, jnp.uint32)
    )
    lone = (dl == sentinel) & (dr == sentinel)
    lpar_is_left = dl <= dr
    lparent = jnp.where(lpar_is_left, i, i + 1)   # node slot (sep i-1 -> node i)
    leaf_ref = ~(i + node_offset)
    wr = owned_leaf & ~lone & lpar_is_left
    wl = owned_leaf & ~lone & ~lpar_is_left
    right = right.at[jnp.where(wr, lparent, n)].set(leaf_ref, mode="drop")
    left = left.at[jnp.where(wl, lparent, n)].set(leaf_ref, mode="drop")
    # Lone leaf: it is its cell's entire tree -> right child of its root slot
    # (which is itself).
    right = right.at[jnp.where(owned_leaf & lone, i, n)].set(leaf_ref, mode="drop")
    leaf_parent = jnp.where(lone, i, lparent)

    # Manual left child of every root slot: the interval overlapping the cell
    # from the left (unreachable when the cell starts exactly at a bound).
    nonempty = counts > 0
    manual = ~jnp.maximum(f_safe + node_offset - 1, 0)
    left = left.at[jnp.where(nonempty, f_safe, n)].set(manual, mode="drop")

    # Guide table.
    table = jnp.where(
        counts == 0,
        ~cell_first,
        jnp.where(overlap == 1, ~(f_safe + node_offset), f_safe + node_offset),
    ).astype(jnp.int32)

    # Traversal depth per leaf -> per-cell fallback flags (paper's degenerate-
    # tree guard: rebuild-as-balanced becomes a per-cell bisection mode).
    depth = jnp.zeros((n,), jnp.int32)
    anc = leaf_parent
    for _ in range(_DEPTH_ITERS):
        live = anc >= 0
        depth = depth + live.astype(jnp.int32)
        anc = jnp.where(live, node_parent[jnp.clip(anc, 0)], anc)
    depth = depth + 1  # the leaf resolution step itself

    cell_depth = jnp.zeros((m_local,), jnp.int32).at[loc_safe].max(
        depth, mode="drop"
    )
    allowed = jnp.ceil(jnp.log2(jnp.maximum(overlap, 2).astype(jnp.float32)))
    fallback = (overlap > 1) & (
        cell_depth > allowed.astype(jnp.int32) + fallback_slack
    )
    return left, right, table, cell_first, fallback


def forest_from_cdf(
    cdf: jax.Array, m: int, fallback_slack: int = 2, d: jax.Array | None = None
) -> RadixForest:
    """Unjitted single-distribution build core — the vmap-safe entry.

    Every op here is batchable, so ``jax.vmap`` over a stacked ``(B, n+1)``
    CDF matrix produces exactly the arrays of B independent builds (the
    fused batched builder in :mod:`repro.pool.batched` rests on this; its
    differential tests pin the bit-identity). ``d`` optionally feeds
    precomputed separator distances (the :mod:`repro.kernels.forest_delta`
    route used by pool delta updates) — they must match
    :func:`_separator_distances` bitwise or the forest silently diverges.
    """
    cdf = jnp.asarray(cdf, jnp.float32)
    n = cdf.shape[0] - 1
    data = lower_bounds(cdf)  # (n,)
    cells = _cells(data, m)
    if d is None:
        d = _separator_distances(data, cells)
    left, right, table, cf, fallback = _build_cell_trees(
        data, d, cells, m=m, cell_lo=0, m_local=m, fallback_slack=fallback_slack
    )
    cell_first = jnp.concatenate([cf, jnp.int32(n - 1)[None]])
    return RadixForest(cdf, table, left, right, cell_first, fallback)


@functools.partial(jax.jit, static_argnames=("m", "fallback_slack"))
def build_forest_from_cdf(
    cdf: jax.Array, m: int, fallback_slack: int = 2
) -> RadixForest:
    """TPU-native massively parallel forest construction (see module doc)."""
    return forest_from_cdf(cdf, m, fallback_slack)


def build_forest(weights: jax.Array, m: int, fallback_slack: int = 2) -> RadixForest:
    """Weights -> CDF (parallel scan) -> forest. The end-to-end build."""
    return build_forest_from_cdf(build_cdf(weights), m, fallback_slack)


# ---------------------------------------------------------------------------
# Faithful Apetrei-style emulation of the paper's Algorithm 1 (ground truth).
# ---------------------------------------------------------------------------


def build_forest_apetrei(cdf: np.ndarray, m: int) -> dict:
    """Round-synchronous numpy emulation of Algorithm 1.

    One logical thread per leaf merges bottom-up; the GPU ``atomicExch`` on
    ``otherBounds[parent]`` is emulated by posting bounds and letting the
    *second* arrival continue (the result is order-independent: the winner
    takes over the identical merged range). Distances use the text's
    "maximum" semantics at cell boundaries (see bits.DIST_SENTINEL note).
    Returns dict(table, left, right) matching :func:`build_forest_from_cdf`.
    """
    cdf = np.asarray(cdf, np.float32)
    n = len(cdf) - 1
    data = np.minimum(cdf[:-1], np.float32(np.nextafter(np.float32(1), np.float32(0))))
    cells = np.clip(np.floor(data * np.float32(m)).astype(np.int64), 0, m - 1)

    def dist(a: int, b: int) -> int:
        """Distance between leaves a and b=a+1 (sentinel at boundaries)."""
        if a < 0 or b > n - 1 or cells[a] != cells[b]:
            return int(DIST_SENTINEL)
        return int(np_xor_distance(data[a : a + 1], data[b : b + 1])[0])

    left = np.full(n, INVALID, np.int64)
    right = np.full(n, INVALID, np.int64)
    other = np.full(n, -1, np.int64)   # otherBounds

    # Thread state: (nodeId, lo, hi); leaves encoded ~i.
    threads = [(~i, i, i) for i in range(n)]
    while threads:
        nxt = []
        for node_id, lo, hi in threads:
            dl, dr = dist(lo - 1, lo), dist(hi, hi + 1)
            if dl == dr == int(DIST_SENTINEL):
                # Cell root (incl. lone leaf): Algorithm 1's tie rule makes it
                # the right child of node range[0] == first leaf of the cell —
                # exactly the root-slot write. Thread terminates.
                right[lo] = node_id
                continue
            child = 0 if dl > dr else 1            # 0 = left child
            parent = hi + 1 if child == 0 else lo
            if child == 0:
                left[parent] = node_id
            else:
                right[parent] = node_id
            # atomicExch(otherBounds[parent], range[child])
            posted = lo if child == 0 else hi
            prev, other[parent] = other[parent], posted
            if prev == -1:
                continue  # first arrival dies; sibling will merge up
            # Second arrival: range[1-child] <- otherBound, continue as parent.
            nlo, nhi = (prev, hi) if child == 1 else (lo, prev)
            nxt.append((parent, nlo, nhi))
        threads = nxt

    # Manual left child per non-empty cell root slot + guide table.
    table = np.zeros(m, np.int64)
    grid = (np.arange(m, dtype=np.float32)) / np.float32(m)
    cf = np.clip(np.searchsorted(data, grid, side="right") - 1, 0, n - 1)
    for c in range(m):
        leaves = np.where(cells == c)[0]
        if len(leaves) == 0:
            table[c] = ~cf[c]
            continue
        f = int(leaves[0])
        overlap = len(leaves) + (1 if data[f] > grid[c] else 0)
        if overlap == 1:
            table[c] = ~f
        else:
            table[c] = f
        left[f] = ~max(f - 1, 0)
    return {
        "table": table.astype(np.int32),
        "left": left.astype(np.int32),
        "right": right.astype(np.int32),
    }


# ---------------------------------------------------------------------------
# Validation / analysis helpers (numpy; used by tests and benchmarks).
# ---------------------------------------------------------------------------


def forest_to_numpy(f: RadixForest) -> dict:
    return {k: np.asarray(v) for k, v in f._asdict().items()}


def validate_forest(f: RadixForest) -> None:
    """Structural invariants; raises AssertionError on violation."""
    fn = forest_to_numpy(f)
    cdf, table, left, right = fn["cdf"], fn["table"], fn["left"], fn["right"]
    n, m = len(left), len(table)
    data = cdf[:-1]
    cells = np.clip(np.floor(data * np.float32(m)).astype(np.int64), 0, m - 1)

    for c in range(m):
        ref = int(table[c])
        leaves = np.where(cells == c)[0]
        if ref < 0:
            i = ~ref
            assert 0 <= i < n
            # the single overlapping interval must cover the cell start
            assert data[i] <= (c / m) + 1e-7 or (len(leaves) == 1 and leaves[0] == i)
            continue
        # In-order traversal of the cell tree must enumerate the cell's
        # leaves in increasing order (plus the manual left-overlap leaf).
        got: list[int] = []
        depth_guard = 0

        def walk(j: int) -> None:
            nonlocal depth_guard
            depth_guard += 1
            assert depth_guard < 10_000
            if j < 0:
                got.append(~j)
                return
            assert 0 <= j < n
            walk(int(left[j]))
            walk(int(right[j]))

        walk(ref)
        f0 = int(leaves[0])
        expect = [max(f0 - 1, 0)] + list(leaves)
        assert got == expect, (c, got, expect)


def depth_stats(f: RadixForest) -> dict:
    """Per-cell traversal depth statistics (node visits to reach a leaf)."""
    fn = forest_to_numpy(f)
    table, left, right = fn["table"], fn["left"], fn["right"]
    n, m = len(left), len(table)
    depths = np.zeros(n, np.int64)

    for c in range(m):
        ref = int(table[c])
        if ref < 0:
            continue
        stack = [(ref, 1)]
        while stack:
            j, dep = stack.pop()
            if j < 0:
                depths[~j] = max(depths[~j], dep)
                continue
            stack.append((int(left[j]), dep + 1))
            stack.append((int(right[j]), dep + 1))
    return {
        "max_depth": int(depths.max(initial=0)),
        "mean_depth": float(depths.mean()) if n else 0.0,
        "depths": depths,
    }
