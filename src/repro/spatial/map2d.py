"""Sharded piecewise-constant 2-D serving: environment/density maps as a
row-marginal forest plus pow2-size-class conditional row stacks.

The paper's headline application (Sec. 5 / Fig. 8) samples a 2-D piecewise
constant distribution — an HDR environment map — as a product: a *marginal*
over rows (one CDF of per-row masses) and one *conditional* per row (that
row's texels). :class:`Map2DSampler` serves exactly that decomposition at
bulk granularity:

* **Marginal** — one :class:`~repro.core.forest.RadixForest` over the H row
  masses. With ``sharded=True`` it is built and drained through
  :mod:`repro.dist.forest` instead (cell-partitioned windowed build,
  owner-routed bulk drain) — the marginal is the map's single large
  distribution, so it is the one worth sharding.
* **Conditionals** — all H row distributions, packed the way
  :class:`repro.pool.ForestPool` packs tenants: rows grouped into
  power-of-two width classes (texel weights zero-padded to the class
  width), each class built by ONE :func:`repro.core.forest2d.build_forest_rows`
  launch (the paper's Sec. 5 simultaneous multi-row pass) and rewrapped by
  :func:`repro.pool.batched.batched_from_row_forest` into the stacked
  :class:`~repro.pool.batched.BatchedForest` layout the batched descent
  kernel wants. H per-row Python builds collapse into one launch per class.

:meth:`Map2DSampler.sample_map` resolves a bulk batch of 2-D points: the
marginal descends on ``u``, then every conditional draw resolves in ONE
:func:`repro.kernels.ops.forest_sample_batched` launch per *touched size
class* with ``dist_id = row`` (coalescing pre-pass included) — never one
launch per distinct sampled row. Single-class unsharded maps take a fully
fused jitted pipeline (marginal descent + conditional descent in one
program). Semantics are exact: class rows behave exactly like
``core.build_forest`` over the zero-padded row (the conformance suite pins
elementwise identity against the per-row reference), and **zero-mass rows
are never selected** — their marginal intervals have zero width, which no
uniform in [0, 1) can hit, so no epsilon fudge is needed (or tolerated:
an epsilon would give empty rows real probability).

:meth:`Map2DSampler.update_map` re-targets a sparse set of rows in O(dirty
rows): per touched class, rows whose new padded CDF bits are unchanged skip
(the same raw-bits skip key as the pool), the truly dirty rows rebuild in
one ``build_forest_rows`` launch and scatter into the class stack — bit-
identical to a from-scratch build because rows of the flat builder never
interact. The marginal re-targets through
:func:`repro.kernels.ops.forest_delta_update` (or
:func:`repro.dist.forest.update_forest_sharded` when sharded), with the
CDF-bits skip deciding whether any rebuild runs at all.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cdf import build_cdf, lower_bounds, normalize_weights
from repro.core.forest import RadixForest, forest_from_cdf
from repro.core.forest2d import build_forest_rows
from repro.kernels import ops, ref
from repro.kernels.forest_sample import forest_sample as _forest_sample_kernel
from repro.pool.arena import _pow2_at_least
from repro.robust.validate import check_policy, sanitize_weights
from repro.pool.batched import BatchedForest, batched_from_row_forest


class _CondClass:
    """One conditional size class: every map row of padded width ``width``
    stacked into a single :class:`BatchedForest` (slot ``s`` holds map row
    ``row_ids[s]``), plus the exact CDF stack the forests were built from
    (the update skip is keyed on its raw bits) and the host-tracked
    degenerate flag that spares drains a device sync."""

    def __init__(self, width: int, row_ids: list[int],
                 forest: BatchedForest, cdf_rows: jax.Array,
                 degenerate: bool):
        self.width = width           # padded texel count = per-row guide m
        self.row_ids = row_ids       # slot -> map row
        self.forest = forest
        self.cdf_rows = cdf_rows     # (B, width+1) f32 — the skip key
        self.degenerate = degenerate
        self.rebuilds = 0            # update_map: rows actually rebuilt
        self.skips = 0               # update_map: bit-unchanged rows


def _marginal_descend(forest: RadixForest, xi, use_pallas: bool,
                      degenerate: bool):
    """Shared-marginal Algorithm 2 with host-tracked degenerate flag (the
    jit-safe core of ``ops.forest_sample``, which instead syncs on the
    device fallback bits and so cannot live inside a fused program)."""
    cf = forest.cell_first if degenerate else None
    fb = forest.fallback if degenerate else None
    if not use_pallas:
        return ref.ref_forest_sample(
            forest.cdf, forest.table, forest.left, forest.right, xi, cf, fb
        )
    return _forest_sample_kernel(
        forest.cdf, forest.table, forest.left, forest.right, xi, cf, fb,
        interpret=jax.default_backend() != "tpu",
    )


@functools.partial(
    jax.jit,
    static_argnames=("use_pallas", "marg_degenerate", "cond_degenerate",
                     "coalesce"),
)
def _fused_sample(marg: RadixForest, cond: BatchedForest, slot_of, widths,
                  u, v, *, use_pallas: bool, marg_degenerate: bool,
                  cond_degenerate: bool, coalesce: bool):
    """The single-class pipeline as ONE program: marginal descent on ``u``,
    slot lookup, batched conditional descent on ``v``, true-width clip."""
    row = _marginal_descend(marg, u, use_pallas, marg_degenerate)
    col = ops.forest_sample_batched(
        cond, slot_of[row], v, use_pallas=use_pallas,
        degenerate=cond_degenerate, coalesce=coalesce,
    )
    return row, jnp.minimum(col, widths[row] - 1)


@jax.jit
def _cdf_stack(weights: jax.Array) -> jax.Array:
    """(B, W) padded weight rows -> (B, W+1) CDF rows. vmap of the scalar
    ``build_cdf`` — the scan grid is per-row, so every row's bits equal an
    independent ``build_cdf`` call (the class-row semantics contract)."""
    return jax.vmap(build_cdf)(weights)


@functools.partial(jax.jit, static_argnames=("m",))
def _rebuild_marginal(cdf: jax.Array, d: jax.Array, m: int) -> RadixForest:
    """Jitted marginal rebuild from a patched CDF + delta-kernel distances."""
    return forest_from_cdf(cdf, m, d=d)


class Map2DSampler:
    """Bulk 2-D piecewise-constant sampling over an environment/density map.

    ``img`` is a 2-D array (H, W) or a ragged list of per-row weight arrays
    (rows may differ in width; each lands in its power-of-two size class,
    floored at ``min_class``). Weights must be non-negative with positive
    total mass; individual rows may be all-zero and are then *exactly*
    unselectable. ``m_marginal`` sets the marginal guide density (default:
    one cell per row). ``sharded=True`` routes the marginal through
    :mod:`repro.dist.forest` (optional ``mesh``/``rebalance``/``routed``
    mirror that module); conditionals stay in stacked class arenas either
    way — they are many *small* trees, exactly the shape the batched kernel
    serves best. ``use_pallas`` defaults to the repo-wide dispatch policy.

    ``policy`` is the per-map weight-admission policy (``reject`` |
    ``clamp`` | ``quarantine`` | ``off``, see :mod:`repro.robust`): each
    row classifies against the structured taxonomy — non-finite or
    negative entries raise under ``reject`` (NaN rows previously slipped
    through to opaque downstream errors) and are repaired / replaced by
    the uniform placeholder under ``clamp``/``quarantine``. All-zero rows
    are NOT violations here: a zero-mass row is exactly unselectable by
    the marginal, the map's long-standing semantics.
    """

    def __init__(self, img, *, m_marginal: int | None = None,
                 min_class: int = 8, sharded: bool = False, mesh=None,
                 rebalance: bool = False, routed: bool = True,
                 use_pallas: bool | None = None, coalesce: bool = True,
                 fallback_slack: int = 2, policy: str = "reject"):
        if min_class < 1 or (min_class & (min_class - 1)):
            raise ValueError("min_class must be a positive power of two")
        self.policy = check_policy(policy)
        rows = [np.asarray(r, np.float64) for r in img]
        if not rows:
            raise ValueError("map must have at least one row")
        rows = [
            sanitize_weights(w, policy, allow_zero_total=True)[0]
            for w in rows
        ]
        self.rows_raw = rows
        self.H = len(rows)
        self.widths = np.asarray([len(w) for w in rows], np.int64)
        self.row_offsets = np.concatenate(
            [[0], np.cumsum(self.widths)]
        ).astype(np.int64)
        self.row_mass = np.asarray([w.sum() for w in rows], np.float64)
        self.min_class = min_class
        self.fallback_slack = fallback_slack
        self.coalesce = coalesce
        self.use_pallas = (
            ops.use_pallas_default() if use_pallas is None else use_pallas
        )
        self.sharded = sharded
        self.routed = routed
        self.last_drain: dict | None = None

        # ---- marginal over row masses (zero-mass rows: zero-width interval)
        self.m_marginal = int(m_marginal) if m_marginal else self.H
        marg_w = normalize_weights(self.row_mass)  # raises on zero total
        if sharded:
            from repro.dist import forest as DF

            self._DF = DF
            self._marginal, self._mesh = DF.build_forest_sharded_auto(
                marg_w, self.m_marginal, mesh=mesh,
                fallback_slack=fallback_slack, rebalance=rebalance,
            )
            self.m_marginal = self._marginal.m  # rounded to a shard multiple
            self._marg_degenerate = False       # sharded drain self-handles
        else:
            cdf = build_cdf(jnp.asarray(marg_w))
            self._marginal = forest_from_cdf(
                cdf, self.m_marginal, fallback_slack=fallback_slack
            )
            self._marg_degenerate = bool(
                jax.device_get(self._marginal.fallback.any())
            )

        # ---- conditionals: one RowForest launch per pow2 width class
        self.classes: dict[int, _CondClass] = {}
        self._class_of = np.empty(self.H, np.int64)  # row -> class width
        self._slot_of = np.empty(self.H, np.int64)   # row -> slot in class
        by_class: dict[int, list[int]] = {}
        for r in range(self.H):
            wc = _pow2_at_least(int(self.widths[r]), min_class)
            by_class.setdefault(wc, []).append(r)
        for wc, rids in sorted(by_class.items()):
            stack = np.stack([self._padded_cond(r, wc) for r in rids])
            cdf_rows = _cdf_stack(jnp.asarray(stack))
            rf = build_forest_rows(cdf_rows, m=wc,
                                   fallback_slack=fallback_slack)
            bf = batched_from_row_forest(rf, cdf_rows)
            degenerate = bool(jax.device_get(bf.fallback.any()))
            self.classes[wc] = _CondClass(wc, rids, bf, cdf_rows, degenerate)
            for slot, r in enumerate(rids):
                self._class_of[r] = wc
                self._slot_of[r] = slot
        self._slot_j = jnp.asarray(self._slot_of, jnp.int32)
        self._widths_j = jnp.asarray(self.widths, jnp.int32)
        # fused single-program pipeline: one class, unsharded marginal
        self._fused = (not sharded) and len(self.classes) == 1

    # ------------------------------------------------------------- plumbing

    def _padded_cond(self, r: int, wc: int) -> np.ndarray:
        """Row ``r``'s conditional weights, normalized and zero-padded to the
        class width. Zero-mass rows get a uniform placeholder: the marginal
        can never select them (zero-width interval), but the class stack
        needs a valid distribution in the slot."""
        w = self.rows_raw[r]
        if self.row_mass[r] <= 0:
            w = np.ones(len(w), np.float64)
        w32 = normalize_weights(w)
        return np.pad(w32, (0, wc - len(w32)))

    def flat_index(self, rows, cols) -> np.ndarray:
        """(row, col) pairs -> flat texel ids over the ragged map layout."""
        return self.row_offsets[np.asarray(rows)] + np.asarray(cols)

    def marginal_weights(self) -> np.ndarray:
        """Normalized float32 row-marginal currently served."""
        return normalize_weights(self.row_mass)

    # ------------------------------------------------------------- sampling

    def _sample_marginal(self, u: jax.Array) -> jax.Array:
        if self.sharded:
            return self._DF.sample_sharded(
                self._marginal, u, mesh=self._mesh, routed=self.routed
            )
        return _marginal_descend(
            self._marginal, u, self.use_pallas, self._marg_degenerate
        )

    def sample_map(self, points2d):
        """Bulk 2-D drain: ``points2d`` (B, 2) uniforms (or a ``(u, v)``
        pair) -> ``(row, col, xi_u, xi_v)`` int32/int32/f32/f32 arrays.

        ``u`` descends the row marginal, ``v`` the selected rows'
        conditionals — ONE batched launch per touched size class with
        ``dist_id`` = the row's class slot (the launch count lands in
        ``self.last_drain``, the structural fact the benchmarks pin).
        Elementwise identical to the per-row ``build_forest`` +
        ``sample_forest`` reference over the padded rows."""
        if isinstance(points2d, tuple):
            u, v = points2d
            u = np.asarray(u, np.float32)
            v = np.asarray(v, np.float32)
        else:
            pts = np.asarray(points2d, np.float32)
            if pts.ndim != 2 or pts.shape[1] != 2:
                raise ValueError("points2d must have shape (B, 2)")
            u, v = pts[:, 0], pts[:, 1]
        if self._fused:
            cls = next(iter(self.classes.values()))
            row, col = _fused_sample(
                self._marginal, cls.forest, self._slot_j, self._widths_j,
                jnp.asarray(u), jnp.asarray(v),
                use_pallas=self.use_pallas,
                marg_degenerate=self._marg_degenerate,
                cond_degenerate=cls.degenerate,
                coalesce=self.coalesce,
            )
            self.last_drain = dict(
                launches=1, fused=True, classes=[cls.width],
                marginal="fused",
            )
            return (np.asarray(row, np.int32), np.asarray(col, np.int32),
                    u, v)

        rows = np.asarray(self._sample_marginal(jnp.asarray(u)), np.int64)
        cols = np.empty(len(rows), np.int32)
        touched = []
        for wc in np.unique(self._class_of[rows]):
            cls = self.classes[int(wc)]
            qs = np.flatnonzero(self._class_of[rows] == wc)
            qpad = _pow2_at_least(len(qs), 64)
            didp = np.full(qpad, -1, np.int32)
            didp[: len(qs)] = self._slot_of[rows[qs]]
            vp = np.pad(v[qs], (0, qpad - len(qs)))
            idx = ops.forest_sample_batched(
                cls.forest, jnp.asarray(didp), jnp.asarray(vp),
                use_pallas=self.use_pallas, degenerate=cls.degenerate,
                coalesce=self.coalesce,
            )
            hi = (self.widths[rows[qs]] - 1).astype(np.int64)
            cols[qs] = np.minimum(
                np.asarray(idx)[: len(qs)], hi
            ).astype(np.int32)
            touched.append(int(wc))
        self.last_drain = dict(
            launches=len(touched), fused=False, classes=touched,
            marginal="sharded" if self.sharded else "direct",
        )
        return rows.astype(np.int32), cols, u, v

    # -------------------------------------------------------------- updates

    def update_map(self, delta_rows: dict, *, delta: bool = False) -> dict:
        """Re-target a sparse set of rows: ``delta_rows`` maps row -> new
        raw weights (or an additive delta with ``delta=True``); widths stay
        fixed. Per touched class, rows whose new padded CDF bits are
        unchanged skip; the truly dirty rows rebuild in ONE
        ``build_forest_rows`` launch and scatter into the class stack —
        bit-identical to a from-scratch :class:`Map2DSampler` over the new
        map (rows of the flat builder never interact). The marginal patches
        through the delta kernel (sharded: ``update_forest_sharded``), with
        its own CDF-bits skip. Returns stats: ``rebuilt_rows`` /
        ``skipped_rows`` (the O(dirty rows) structural witness),
        ``cond_launches``, ``marginal_rebuilt``."""
        by_class: dict[int, list[int]] = {}
        for r, w in delta_rows.items():
            r = int(r)
            if not 0 <= r < self.H:
                raise ValueError(f"row {r} out of range")
            w = np.asarray(w, np.float64)
            if w.shape != (int(self.widths[r]),):
                raise ValueError(
                    f"update keeps widths fixed: row {r} has width "
                    f"{int(self.widths[r])}, got shape {w.shape}"
                )
            raw = self.rows_raw[r] + w if delta else w
            # same admission policy as construction (reject raises the
            # structured class before any map state moves)
            raw = sanitize_weights(raw, self.policy, allow_zero_total=True)[0]
            self.rows_raw[r] = raw
            self.row_mass[r] = raw.sum()
            by_class.setdefault(int(self._class_of[r]), []).append(r)

        stats = dict(rebuilt_rows=0, skipped_rows=0, cond_launches=0,
                     marginal_rebuilt=False)
        for wc, rids in sorted(by_class.items()):
            cls = self.classes[wc]
            slots = np.asarray([self._slot_of[r] for r in rids], np.int64)
            stack = np.stack([self._padded_cond(r, wc) for r in rids])
            new_cdf = _cdf_stack(jnp.asarray(stack))
            old_bits = np.asarray(cls.cdf_rows)[slots].view(np.uint32)
            new_bits = np.asarray(new_cdf).view(np.uint32)
            dirty = np.flatnonzero((old_bits != new_bits).any(axis=1))
            stats["skipped_rows"] += len(rids) - len(dirty)
            cls.skips += len(rids) - len(dirty)
            if len(dirty) == 0:
                continue
            # one multi-row launch for the class's dirty rows, padded to a
            # pow2 batch (repeat row 0) so update sizes share programs
            dpad = _pow2_at_least(len(dirty), 8)
            sel = np.concatenate(
                [dirty, np.zeros(dpad - len(dirty), np.int64)]
            )
            cdf_dirty = new_cdf[jnp.asarray(sel)]
            rf = build_forest_rows(cdf_dirty, m=wc,
                                   fallback_slack=self.fallback_slack)
            built = batched_from_row_forest(rf, cdf_dirty)
            idx = jnp.asarray(slots[dirty], jnp.int32)
            cls.forest = BatchedForest(
                *(a.at[idx].set(b[: len(dirty)])
                  for a, b in zip(cls.forest, built))
            )
            cls.cdf_rows = cls.cdf_rows.at[idx].set(
                new_cdf[jnp.asarray(dirty)]
            )
            cls.degenerate = bool(jax.device_get(cls.forest.fallback.any()))
            cls.rebuilds += len(dirty)
            stats["rebuilt_rows"] += len(dirty)
            stats["cond_launches"] += 1

        # ---- marginal delta (row masses may have moved)
        marg_w = normalize_weights(self.row_mass)
        if self.sharded:
            self._marginal, mst = self._DF.update_forest_sharded(
                self._marginal, marg_w, mesh=self._mesh,
                fallback_slack=self.fallback_slack, with_stats=True,
            )
            stats["marginal_rebuilt"] = bool(mst["rebuilt"])
            stats["marginal_shards"] = mst
        else:
            new_cdf = build_cdf(jnp.asarray(marg_w))
            old_cdf = self._marginal.cdf
            if np.array_equal(
                np.asarray(old_cdf).view(np.uint32),
                np.asarray(new_cdf).view(np.uint32),
            ):
                return stats
            d_new, _ = ops.forest_delta_update(
                lower_bounds(old_cdf), lower_bounds(new_cdf),
                self.m_marginal, use_pallas=self.use_pallas,
            )
            self._marginal = _rebuild_marginal(
                new_cdf, d_new, self.m_marginal
            )
            self._marg_degenerate = bool(
                jax.device_get(self._marginal.fallback.any())
            )
            stats["marginal_rebuilt"] = True
        return stats

    # ---------------------------------------------------------- inspection

    def stats(self) -> dict:
        """Per-class shape/update counters + marginal coordinates."""
        return dict(
            H=self.H,
            m_marginal=self.m_marginal,
            sharded=self.sharded,
            policy=self.policy,
            classes={
                wc: dict(rows=len(c.row_ids), rebuilds=c.rebuilds,
                         skips=c.skips, degenerate=c.degenerate)
                for wc, c in sorted(self.classes.items())
            },
        )
