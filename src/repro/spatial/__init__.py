"""2-D piecewise-constant serving: the paper's environment-map application
(marginal-over-rows x conditional-per-row) at bulk batched granularity."""
from .map2d import Map2DSampler

__all__ = ["Map2DSampler"]
