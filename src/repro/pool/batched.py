"""Fused batched forest construction: B distributions in one launch.

The paper parallelizes construction *within* one distribution; the serving
north star needs thousands of *small* distributions built concurrently
(per-request token priors, per-cell densities, per-client mixtures), where a
launch per distribution wastes the machine. Hübschle-Schneider & Sanders
(2019) make the case that bulk/batched queries are the right granularity for
parallel samplers; this module applies the same logic to *construction*: the
whole build core (chunked CDF scan -> separator distances -> nearest-greater
descent -> cell trees) is data-parallel per distribution, so ``jax.vmap``
over a stacked ``(B, n)`` weight matrix turns B builds into one fused
program whose every row is **bit-identical** to an independent
``core.build_forest`` call (the differential tests in ``tests/test_pool.py``
pin this per weight family and size).

:class:`BatchedForest` is the packed-table layout Lehmann et al. (2021) show
batched GPU sampling wants: all B forests stacked row-major, so the batched
sampling kernel (:func:`repro.kernels.forest_sample.forest_sample_batched`)
resolves a mixed ``(dist_id, uniform)`` batch with flat row-offset gathers —
one launch, no per-distribution dispatch.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.alias import AliasTable
from repro.core.cdf import build_cdf
from repro.core.forest import RadixForest, forest_from_cdf


class BatchedAlias(NamedTuple):
    """B stacked packed alias tables over a shared size class — the
    O(1)-per-draw twin of :class:`BatchedForest` for PRNG tenants.

    Row ``b`` is exactly the :class:`repro.core.alias.AliasTable` of
    distribution ``b`` (``alias`` entries are row-local cell indices).
    Half the footprint of a forest row (8 bytes/cell) and two gathers per
    draw; the price is a non-monotone map, so QMC tenants stay on the
    forest stack."""

    q: jax.Array      # (B, n) f32 split point within each cell
    alias: jax.Array  # (B, n) i32 second interval of each cell

    @property
    def batch(self) -> int:
        return self.q.shape[0]

    @property
    def n(self) -> int:
        return self.q.shape[1]

    def row(self, b: int) -> AliasTable:
        """Single-distribution view (differential tests; serving drains
        through the batched kernel)."""
        return AliasTable(self.q[b], self.alias[b])


class BatchedForest(NamedTuple):
    """B stacked radix forests over a shared (n, m) shape class.

    Row ``b`` is exactly the :class:`repro.core.forest.RadixForest` of
    distribution ``b``: all references (node ids, leaf refs ``~i``, guide
    entries) are *row-local*, so sampling returns per-distribution interval
    indices. Stacking is the whole point — one compiled program per (B, n, m)
    shape serves every distribution in the batch."""

    cdf: jax.Array         # (B, n+1) f32
    table: jax.Array       # (B, m)   i32
    left: jax.Array        # (B, n)   i32
    right: jax.Array       # (B, n)   i32
    cell_first: jax.Array  # (B, m+1) i32
    fallback: jax.Array    # (B, m)   bool

    @property
    def batch(self) -> int:
        return self.left.shape[0]

    @property
    def n(self) -> int:
        return self.left.shape[1]

    @property
    def m(self) -> int:
        return self.table.shape[1]

    def row(self, b: int) -> RadixForest:
        """Single-distribution view (host-side debugging / differential
        tests; sampling should go through the batched kernel instead)."""
        return RadixForest(*(x[b] for x in self))


@functools.partial(jax.jit, static_argnames=("m", "fallback_slack"))
def build_forest_batched_from_cdf(
    cdf: jax.Array, m: int, fallback_slack: int = 2
) -> BatchedForest:
    """(B, n+1) stacked CDFs -> B forests in one fused program."""
    f = jax.vmap(lambda c: forest_from_cdf(c, m, fallback_slack))(
        jnp.asarray(cdf, jnp.float32)
    )
    return BatchedForest(*f)


@functools.partial(jax.jit, static_argnames=("m", "fallback_slack"))
def build_forest_batched(
    weights: jax.Array, m: int, fallback_slack: int = 2
) -> BatchedForest:
    """The fused end-to-end batched build: (B, n) weights -> B forests.

    Each row runs the *same* chunked-scan CDF + forest build as
    ``core.build_forest`` (the scan grid is per-row, so vmapping does not
    reassociate any addition) — row ``b`` of the result is bit-identical to
    ``build_forest(weights[b], m)``."""
    f = jax.vmap(lambda w: forest_from_cdf(build_cdf(w), m, fallback_slack))(
        jnp.asarray(weights, jnp.float32)
    )
    return BatchedForest(*f)


@jax.jit
def batched_from_row_forest(rows, cdf_rows: jax.Array) -> BatchedForest:
    """Rewrap a flat :class:`repro.core.forest2d.RowForest` as a
    :class:`BatchedForest` — one-pass multi-row construction feeding the
    batched descent kernel.

    The flat builder emits *global* references (leaf ``~i`` and node ids
    index the flat ``(R*W,)`` arrays, guide entries index ``(R*m,)`` cells);
    the batched kernel wants *row-local* ones. Because row ``r``'s nodes and
    leaves all live in ``[r*W, (r+1)*W)``, the rewrite is a per-row offset
    subtraction: for a reference ``v`` in row ``r`` with ``off = r*W``,
    ``local = v - off`` when ``v >= 0`` (node id) and ``v + off`` when
    ``v < 0`` (leaf, since ``~(i - off) = ~i + off``). Row ``r`` of the
    result is bit-identical to ``forest_from_cdf(cdf_rows[r], m)`` — the
    spatial conformance suite pins this, fallback flags included.

    ``cdf_rows`` must be the exact ``(R, W+1)`` CDF stack the RowForest was
    built from: the batched kernel compares against the *unclamped* CDF
    (matching single builds), not the clamped flat ``data``."""
    R, W1 = cdf_rows.shape  # static, unlike the RowForest int leaves
    W = W1 - 1
    m = rows.table.shape[0] // R
    off = (jnp.arange(R, dtype=jnp.int32) * W)[:, None]

    def local(v):
        return jnp.where(v >= 0, v - off, v + off)

    cell_first = jnp.concatenate(
        [
            rows.cell_first[:-1].reshape(R, m) - off,
            jnp.full((R, 1), W - 1, jnp.int32),
        ],
        axis=1,
    )
    return BatchedForest(
        cdf=jnp.asarray(cdf_rows, jnp.float32),
        table=local(rows.table.reshape(R, m)),
        left=local(rows.left.reshape(R, W)),
        right=local(rows.right.reshape(R, W)),
        cell_first=cell_first,
        fallback=rows.fallback.reshape(R, m),
    )


def sample_forest_batched(
    forest: BatchedForest,
    dist_id: jax.Array,
    xi: jax.Array,
    use_pallas: bool = True,
) -> jax.Array:
    """Bulk mixed-batch sampling: draw ``q`` resolves uniform ``xi[q]`` in
    distribution ``dist_id[q]``'s tree — one launch for the whole batch.
    Thin re-export of :func:`repro.kernels.ops.forest_sample_batched` so
    pool callers never import the kernel layer directly."""
    from repro.kernels import ops

    return ops.forest_sample_batched(forest, dist_id, xi, use_pallas=use_pallas)


def build_alias_batched(weights: jax.Array, use_pallas: bool = True) -> BatchedAlias:
    """The fused batched alias build: (B, n) weights -> B packed tables in
    one program (``kernels.alias_build``; the ref and kernel paths share
    the row core, so both are bit-identical). Rows with exact dyadic
    weights match ``core.alias.build_alias_parallel`` bit for bit."""
    from repro.kernels import ops

    return BatchedAlias(*ops.alias_build_batched(
        jnp.asarray(weights, jnp.float32), use_pallas=use_pallas
    ))


def sample_alias_batched(
    table: BatchedAlias,
    dist_id: jax.Array,
    xi: jax.Array,
    use_pallas: bool = True,
) -> jax.Array:
    """Bulk mixed-batch alias drain: draw ``q`` resolves uniform ``xi[q]``
    in distribution ``dist_id[q]``'s packed table — O(1) per lane, one
    launch for the whole batch. Thin re-export of
    :func:`repro.kernels.ops.alias_sample_batched`."""
    from repro.kernels import ops

    return ops.alias_sample_batched(table, dist_id, xi, use_pallas=use_pallas)
