"""Size-class arenas with per-tenant sampling *method*: many variable-n
tenants, few compiled programs, two drain paths.

The multi-tenant serving problem: thousands of clients each own a *small*
categorical of a *different* size, churning (insert / re-weight / evict) at
request rate. Naively that is one compiled build + one compiled sampler per
distinct ``n`` — a recompile storm. :class:`ForestPool` packs tenants into
**power-of-two size classes** (weights zero-padded to the class size), so
every tenant in a class shares the same stacked arrays and the same handful
of compiled programs — regardless of how many tenants come and go.

Each tenant now also declares HOW it is sampled (the paper's central
tradeoff, made a per-slot attribute):

* ``method="forest"`` — the monotone radix-forest map
  (:class:`~repro.pool.batched.BatchedForest` stacks, ``_SizeClass``
  arenas). Preserves QMC stratification; this is the path for
  stream-sensitive tenants (best-of-n decode, stratified sweeps) and the
  default.
* ``method="alias"`` — packed Walker/Vose tables
  (:class:`~repro.pool.batched.BatchedAlias` stacks, :class:`AliasArena`
  arenas) built by the fused split-and-pack kernel. O(1) per draw, ~100x
  the forest drain's bulk throughput, but a **non-monotone** map that
  destroys low-discrepancy structure — for bulk PRNG tenants only.

Both arena kinds share one slot-lifecycle machine (:class:`_Arena`):
:meth:`ForestPool.insert` hands out a stable :class:`Handle` (size class,
row, true ``n``, version, method). Rows are recycled through a **free
list**; every recycle bumps the row's **version counter**, so a stale
handle (evicted tenant, reused slot) raises instead of silently sampling
someone else's distribution. :meth:`ForestPool.update_weights` re-targets
a tenant in place — forest rows route the Algorithm-1 re-work through
:mod:`repro.kernels.forest_delta` (a bit-identical CDF skips the rebuild),
alias rows re-pack (a bit-identical padded weight row skips). Eviction
clears the freed row's arena state (fallback flags on the forest side, the
packed table row on the alias side).

Zero-padding is sound on both paths: padded forest intervals have zero
width, and padded alias cells are full-deficit lights with ``q == 0`` that
are never an alias target — no uniform in [0, 1) ever resolves to either.

**Admission policy** (the :mod:`repro.robust` boundary): every weight row
entering the pool (``insert`` / ``insert_many`` / ``update_weights``) is
classified against the invariants a monotone CDF needs — finite entries,
no negatives, a positive total that survives the f64 normalize — with a
structured taxonomy (``non_finite`` / ``negative`` / ``zero_total`` /
``overflow_on_pad``, each a ``ValueError`` subclass in
:mod:`repro.robust.errors`). The per-pool ``policy`` decides what a
violation does: ``reject`` (default) raises before anything touches an
arena row; ``clamp`` repairs (NaN->0, +Inf->f32max, negatives->0, then a
uniform placeholder if the total is zero) and admits the repaired row;
``quarantine`` admits a uniform placeholder and flags the handle
(``is_quarantined`` / ``stats()['quarantined']``; ``weights()`` refuses;
a later clean ``update_weights`` clears the flag) — co-tenants in the
same packed batch are untouched in every case. ``off`` skips validation
(benchmark witness only). Stale handles raise
:class:`~repro.robust.errors.StaleHandleError`.

Draining groups draws by ``(method, size class)`` and issues ONE batched
kernel launch per touched group — ``forest_sample_batched`` /
``alias_sample_batched``, or their stream-aware forms under
:meth:`ForestPool.sample_streams`, where per-slot QMC stream state lives
on device (``DeviceQmcStreams`` protocol: ``draw(slots) -> (ctr,
offset_bits, xi)``); forest groups recompute the stream points in-kernel,
alias groups consume the pre-pass points (QMC tenants should stay on the
forest path — serving's ``auto`` method does exactly that). All lanes pad
to power-of-two buckets with **sentinel** dist ids (``-1``): a sentinel
lane resolves to a no-op instead of reading a freed row's stale arrays.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cdf import build_cdf, lower_bounds, normalize_weights
from repro.core.alias import AliasTable
from repro.core.forest import RadixForest, forest_from_cdf
from repro.kernels import ops
from repro.robust.errors import QuarantinedError, StaleHandleError
from repro.robust.validate import check_policy, sanitize_weights

from .batched import BatchedAlias, BatchedForest, build_forest_batched

METHODS = ("forest", "alias")


class Handle(NamedTuple):
    """Stable tenant reference: which class/row, how big, which lifetime,
    and which sampling method its row lives under (``method`` keys the
    arena kind — a forest handle can never resolve against an alias row)."""

    size_class: int  # padded n (power of two) — the class key
    row: int         # row in the class's stacked arrays
    n: int           # true (unpadded) distribution size
    version: int     # row lifetime counter; mismatch => stale handle
    method: str = "forest"  # "forest" (monotone) | "alias" (O(1), PRNG-only)


def _pow2_at_least(x: int, floor: int) -> int:
    p = max(int(floor), 1)
    while p < x:
        p <<= 1
    return p


class _Arena:
    """The shared size-class slot machine: pow2-padded rows, free-list
    recycling, per-row version counters, raw-weight shadow copies. Payload
    storage (forest stacks vs packed alias stacks) is the subclass's
    business via :meth:`_grow_payload`."""

    def __init__(self, size: int, init_rows: int):
        self.size = size
        self.rows = init_rows
        self.n_true = np.zeros(init_rows, np.int64)
        self.versions = np.zeros(init_rows, np.int64)
        self.free: list[int] = list(range(init_rows - 1, -1, -1))
        self.raw: dict[int, np.ndarray] = {}  # row -> float64 raw weights
        self.builds = 0
        self.grows = 0

    @property
    def occupied(self) -> int:
        return self.rows - len(self.free)

    def _grow_payload(self, extra: int) -> None:
        raise NotImplementedError

    def grow(self) -> None:
        extra = self.rows
        self.free.extend(range(self.rows + extra - 1, self.rows - 1, -1))
        self._grow_payload(extra)
        self.n_true = np.concatenate([self.n_true, np.zeros(extra, np.int64)])
        self.versions = np.concatenate([self.versions, np.zeros(extra, np.int64)])
        self.rows += extra
        self.grows += 1

    def take_row(self) -> int:
        if not self.free:
            self.grow()
        return self.free.pop()


class _SizeClass(_Arena):
    """One stacked forest arena: all tenants padded to ``size`` leaves."""

    def __init__(self, size: int, m: int, init_rows: int):
        super().__init__(size, init_rows)
        self.m = m
        self.forest: BatchedForest | None = None  # allocated on first build
        self.degenerate_rows: set[int] = set()  # rows with flagged cells
        self.delta_rebuilds = 0
        self.delta_skips = 0

    def _grow_payload(self, extra: int) -> None:
        if self.forest is not None:
            pad = _zeros_forest(extra, self.size, self.m)
            self.forest = BatchedForest(
                *(jnp.concatenate([a, b]) for a, b in zip(self.forest, pad))
            )


class AliasArena(_Arena):
    """One stacked packed-alias arena: the PRNG fast path's payload.

    Same lifecycle as the forest classes (free list, versions, raw
    shadows); the payload is a :class:`~repro.pool.batched.BatchedAlias`
    stack written by the fused split-and-pack build. ``rebuilds``/``skips``
    count :meth:`ForestPool.update_weights` work (a bit-unchanged padded
    weight row skips the re-pack)."""

    def __init__(self, size: int, init_rows: int):
        super().__init__(size, init_rows)
        self.table: BatchedAlias | None = None  # allocated on first build
        self.rebuilds = 0
        self.skips = 0

    def _grow_payload(self, extra: int) -> None:
        if self.table is not None:
            pad = _zeros_alias(extra, self.size)
            self.table = BatchedAlias(
                *(jnp.concatenate([a, b]) for a, b in zip(self.table, pad))
            )


def _zeros_forest(rows: int, n: int, m: int) -> BatchedForest:
    """Placeholder stack for never-occupied rows (no draw ever routes to a
    row without a live handle, so content only needs valid shapes/dtypes)."""
    return BatchedForest(
        cdf=jnp.zeros((rows, n + 1), jnp.float32),
        table=jnp.zeros((rows, m), jnp.int32),
        left=jnp.zeros((rows, n), jnp.int32),
        right=jnp.zeros((rows, n), jnp.int32),
        cell_first=jnp.zeros((rows, m + 1), jnp.int32),
        fallback=jnp.zeros((rows, m), jnp.bool_),
    )


def _zeros_alias(rows: int, n: int) -> BatchedAlias:
    """Placeholder/cleared alias rows: ``q == 0`` with self-aliases — inert
    even if read (every draw resolves to cell 0's alias 0)."""
    return BatchedAlias(
        q=jnp.zeros((rows, n), jnp.float32),
        alias=jnp.zeros((rows, n), jnp.int32),
    )


class ForestPool:
    """A batched two-method sampling pool over power-of-two size-class
    arenas: radix forests for stream-sensitive (QMC) tenants, packed alias
    tables for bulk PRNG tenants, selected per slot at admission.

    Parameters: ``min_class`` floors the smallest padded size (tiny tenants
    share one class instead of one class per n); ``m`` pins one guide
    resolution for every forest class (default: each class uses
    ``m = size``, the repo-wide guide density); ``init_rows`` is the
    starting arena height, doubled on demand. Forest and alias arenas are
    disjoint per size (``classes`` / ``alias_classes``); a handle's
    ``method`` routes every pool call to the right one. ``policy`` sets the
    weight-admission behavior (``reject`` | ``clamp`` | ``quarantine`` |
    ``off`` — see the module docstring for the taxonomy).
    """

    def __init__(self, min_class: int = 8, m: int | None = None,
                 init_rows: int = 4, policy: str = "reject"):
        if min_class < 1 or (min_class & (min_class - 1)):
            raise ValueError("min_class must be a positive power of two")
        self.min_class = min_class
        self._m = m
        self.init_rows = max(int(init_rows), 1)
        self.policy = check_policy(policy)
        self.classes: dict[int, _SizeClass] = {}
        self.alias_classes: dict[int, AliasArena] = {}
        # (method, size_class, row, version) of handles admitted under the
        # quarantine policy: serving a uniform placeholder, flag queryable.
        self.quarantined: set[tuple[str, int, int, int]] = set()

    # ------------------------------------------------------------- plumbing

    def _class_for(self, n: int, method: str = "forest") -> _Arena:
        if method not in METHODS:
            raise ValueError(f"unknown sampling method {method!r}; "
                             f"expected one of {METHODS}")
        size = _pow2_at_least(n, self.min_class)
        if method == "alias":
            ar = self.alias_classes.get(size)
            if ar is None:
                ar = AliasArena(size, self.init_rows)
                self.alias_classes[size] = ar
            return ar
        sc = self.classes.get(size)
        if sc is None:
            sc = _SizeClass(size, self._m or size, self.init_rows)
            self.classes[size] = sc
        return sc

    def _check(self, h: Handle) -> _Arena:
        # O(1): ``raw`` holds exactly the occupied rows (insert sets, evict
        # pops), and evict bumps the version BEFORE freeing, so a recycled
        # row can never satisfy a stale handle's version. The method field
        # picks the arena table, so a forest handle can never validate
        # against an alias row of the same (size, row) coordinates.
        table = self.alias_classes if h.method == "alias" else self.classes
        sc = table.get(h.size_class)
        if (
            sc is None
            or h.row not in sc.raw
            or sc.versions[h.row] != h.version
        ):
            raise StaleHandleError(f"stale or evicted handle: {h}")
        return sc

    @staticmethod
    def _qkey(h: Handle) -> tuple[str, int, int, int]:
        return (h.method, h.size_class, h.row, h.version)

    def is_quarantined(self, handle: Handle) -> bool:
        """True if the (live) handle was admitted under ``quarantine`` and
        has not since been cleared by a clean ``update_weights``."""
        self._check(handle)
        return self._qkey(handle) in self.quarantined

    def _pad(self, w: np.ndarray, size: int) -> np.ndarray:
        return np.pad(w.astype(np.float32), (0, size - len(w)))

    def _write_rows(self, sc: _SizeClass, rows: list[int],
                    built: BatchedForest) -> None:
        if sc.forest is None:
            sc.forest = _zeros_forest(sc.rows, sc.size, sc.m)
        idx = jnp.asarray(rows, jnp.int32)
        sc.forest = BatchedForest(
            *(a.at[idx].set(b) for a, b in zip(sc.forest, built))
        )

    def _write_alias_rows(self, ar: AliasArena, rows: list[int],
                          built: BatchedAlias) -> None:
        if ar.table is None:
            ar.table = _zeros_alias(ar.rows, ar.size)
        idx = jnp.asarray(rows, jnp.int32)
        ar.table = BatchedAlias(
            *(a.at[idx].set(b) for a, b in zip(ar.table, built))
        )

    # ------------------------------------------------------------ lifecycle

    def insert(self, weights, method: str = "forest") -> Handle:
        """Admit one tenant; see :meth:`insert_many` for the fused path."""
        return self.insert_many([weights], method=method)[0]

    def insert_many(self, weights_list, method="forest") -> list[Handle]:
        """Admit a group of tenants, fusing each (method, size class)
        group's builds into ONE batched launch (``build_forest_batched`` /
        the split-and-pack alias kernel over the stacked padded rows) — the
        build-B-at-once path the pool exists for. ``method`` is a single
        method for the whole wave or a per-tenant sequence
        (``"forest"``/``"alias"``). The group is padded to a power-of-two
        batch so heterogeneous admission waves reuse a logarithmic number
        of compiled build programs.

        Every row passes the pool's admission policy first: under
        ``reject`` a bad row raises (taxonomy class per violation) before
        any arena row is taken; under ``clamp``/``quarantine`` the
        repaired/placeholder row is what gets built, so a poisoned
        submission can never corrupt the packed batch it shares with
        co-tenants."""
        sanitized = [sanitize_weights(w, self.policy) for w in weights_list]
        raws = [r for r, _ in sanitized]
        if isinstance(method, str):
            methods = [method] * len(raws)
        else:
            methods = list(method)
        if len(methods) != len(raws):
            raise ValueError("method list must align with weights_list")
        norms = [normalize_weights(r) for r in raws]
        handles: list[Handle | None] = [None] * len(raws)
        by_group: dict[tuple[str, int], list[int]] = {}
        for i, w in enumerate(norms):
            ar = self._class_for(len(w), methods[i])
            by_group.setdefault((methods[i], ar.size), []).append(i)
        for (meth, size), idxs in by_group.items():
            ar = self._class_for(size, meth)
            rows = [ar.take_row() for _ in idxs]
            stack = np.stack([self._pad(norms[i], size) for i in idxs])
            bpad = _pow2_at_least(len(idxs), 1)
            if bpad != len(idxs):  # dummy rows keep the program count low
                fill = np.full((bpad - len(idxs), size), 1.0, np.float32)
                stack = np.concatenate([stack, fill])
            if meth == "alias":
                q, a = ops.alias_build_batched(
                    jnp.asarray(stack), use_pallas=ops.use_pallas_default()
                )
                self._write_alias_rows(
                    ar, rows, BatchedAlias(q[: len(idxs)], a[: len(idxs)])
                )
                ar.builds += len(idxs)
                for i, row in zip(idxs, rows):
                    ar.n_true[row] = len(norms[i])
                    ar.raw[row] = raws[i]
                    handles[i] = Handle(
                        size, row, len(norms[i]), int(ar.versions[row]), "alias"
                    )
                    if sanitized[i][1]:
                        self.quarantined.add(self._qkey(handles[i]))
                continue
            built = build_forest_batched(jnp.asarray(stack), ar.m)
            built = BatchedForest(*(x[: len(idxs)] for x in built))
            self._write_rows(ar, rows, built)
            ar.builds += len(idxs)
            # one sync per admission wave keeps the drain path sync-free
            flagged = np.asarray(built.fallback.any(axis=1))
            for (i, row), flag in zip(zip(idxs, rows), flagged):
                ar.n_true[row] = len(norms[i])
                ar.raw[row] = raws[i]
                if flag:
                    ar.degenerate_rows.add(row)
                handles[i] = Handle(size, row, len(norms[i]),
                                    int(ar.versions[row]))
                if sanitized[i][1]:
                    self.quarantined.add(self._qkey(handles[i]))
        return handles  # type: ignore[return-value]

    def update_weights(self, handle: Handle, weights=None, *, delta=None) -> None:
        """In-place re-target of one tenant (full weights or a delta on the
        raw weights). Forest rows route the Algorithm-1 re-work through
        :func:`repro.kernels.ops.forest_delta_update`: bit-unchanged CDFs
        skip the rebuild; otherwise the returned separator distances feed a
        single-row rebuild. Alias rows re-run the split-and-pack on the one
        padded row, with the skip keyed on the padded float32 weight bits.
        The handle stays valid (versions track slot reuse, not content).

        The resulting raw row passes the pool's admission policy: a retune
        that goes bad (all-zero total, a delta driving entries negative,
        NaN poisoning) raises the taxonomy class under ``reject``, is
        repaired under ``clamp``, or swaps the row to the uniform
        placeholder and flags the handle under ``quarantine`` — and a
        clean update clears a standing quarantine flag."""
        sc = self._check(handle)
        if (weights is None) == (delta is None):
            raise ValueError("pass exactly one of weights or delta")
        for name, arr in (("weights", weights), ("delta", delta)):
            if arr is not None and np.asarray(arr).shape != (handle.n,):
                raise ValueError(
                    f"update keeps n fixed: handle has n={handle.n}, got "
                    f"{name} of shape {np.asarray(arr).shape} (scalars and "
                    f"padded-size arrays would silently broadcast)"
                )
        old_raw = sc.raw[handle.row]
        if weights is None:
            proposed = np.asarray(old_raw, np.float64) + np.asarray(delta, np.float64)
        else:
            proposed = np.asarray(weights, np.float64)
        # reject raises here, BEFORE the shadow copy or any arena row moves
        raw, quarantine = sanitize_weights(proposed, self.policy)
        w = normalize_weights(raw)
        if quarantine:
            self.quarantined.add(self._qkey(handle))
        else:
            self.quarantined.discard(self._qkey(handle))
        sc.raw[handle.row] = raw
        if handle.method == "alias":
            new_row = self._pad(w, sc.size)
            old_row = self._pad(normalize_weights(old_raw), sc.size)
            # skip keyed on the exact bits the table is a function of
            if np.array_equal(new_row.view(np.uint32), old_row.view(np.uint32)):
                sc.skips += 1
                return
            q, a = ops.alias_build_batched(
                jnp.asarray(new_row[None]), use_pallas=ops.use_pallas_default()
            )
            self._write_alias_rows(sc, [handle.row], BatchedAlias(q, a))
            sc.rebuilds += 1
            return
        new_cdf = build_cdf(jnp.asarray(self._pad(w, sc.size)))
        old_cdf = sc.forest.cdf[handle.row]
        # Skip keyed on raw CDF bits (the dist-layer policy): the clamped
        # lower bounds alone could hide a cdf move inside the last-ulp-
        # below-1 region and leave a stale row serving.
        if np.array_equal(
            np.asarray(old_cdf).view(np.uint32),
            np.asarray(new_cdf).view(np.uint32),
        ):
            sc.delta_skips += 1
            return
        d_new, _ = ops.forest_delta_update(
            lower_bounds(old_cdf), lower_bounds(new_cdf), sc.m,
            use_pallas=ops.use_pallas_default(),
        )
        built = _rebuild_row(new_cdf, d_new, sc.m)
        self._write_rows(sc, [handle.row], BatchedForest(
            *(a[None] for a in built)
        ))
        if bool(jax.device_get(built.fallback.any())):
            sc.degenerate_rows.add(handle.row)
        else:
            sc.degenerate_rows.discard(handle.row)
        sc.delta_rebuilds += 1

    def evict(self, handle: Handle) -> None:
        """Release the tenant's row back to its arena's free list. The
        version bump invalidates every outstanding handle to the row, and
        the freed row's arena state is cleared: forest rows drop their
        fallback bits (a dead degenerate tenant must not force the
        side-table pre-resolution path on the whole class's future drains),
        alias rows zero their packed table (a cleared row is inert even if
        a bug ever routed a lane into it)."""
        sc = self._check(handle)
        self.quarantined.discard(self._qkey(handle))
        sc.versions[handle.row] += 1
        sc.n_true[handle.row] = 0
        sc.raw.pop(handle.row, None)
        sc.free.append(handle.row)
        if handle.method == "alias":
            if sc.table is not None:
                sc.table = BatchedAlias(
                    q=sc.table.q.at[handle.row].set(0.0),
                    alias=sc.table.alias.at[handle.row].set(0),
                )
            return
        if handle.row in sc.degenerate_rows:
            sc.degenerate_rows.discard(handle.row)
            sc.forest = sc.forest._replace(
                fallback=sc.forest.fallback.at[handle.row].set(False)
            )

    # ---------------------------------------------------------- persistence

    def snapshot(self) -> dict:
        """Full serving-state snapshot: every arena payload, free list,
        version counter, raw-weight shadow, and quarantine flag — the
        nested-dict form :func:`repro.ckpt.save_state` commits atomically.
        A pool restored from it (:meth:`restore`) validates every
        outstanding :class:`Handle` and produces bit-identical drains."""

        def common(ar: _Arena) -> dict:
            return dict(
                size=ar.size, rows=ar.rows,
                n_true=ar.n_true.copy(), versions=ar.versions.copy(),
                free=list(ar.free),
                raw={int(r): np.asarray(v) for r, v in ar.raw.items()},
                builds=ar.builds, grows=ar.grows,
            )

        classes = {}
        for size, sc in self.classes.items():
            d = common(sc)
            d.update(
                m=sc.m,
                degenerate_rows=set(sc.degenerate_rows),
                delta_rebuilds=sc.delta_rebuilds,
                delta_skips=sc.delta_skips,
                forest=None if sc.forest is None
                else [np.asarray(x) for x in sc.forest],
            )
            classes[int(size)] = d
        alias_classes = {}
        for size, ar in self.alias_classes.items():
            d = common(ar)
            d.update(
                rebuilds=ar.rebuilds, skips=ar.skips,
                table=None if ar.table is None
                else [np.asarray(x) for x in ar.table],
            )
            alias_classes[int(size)] = d
        return dict(
            kind="forest_pool",
            policy=self.policy, min_class=self.min_class, m=self._m,
            init_rows=self.init_rows,
            quarantined=set(self.quarantined),
            classes=classes, alias_classes=alias_classes,
        )

    @classmethod
    def restore(cls, state: dict) -> "ForestPool":
        """Rebuild a pool from :meth:`snapshot` output (live or round-
        tripped through :func:`repro.ckpt.load_state`). Handles issued by
        the snapshotted pool stay valid — versions are part of the state —
        and subsequent drains are bit-identical."""
        if state.get("kind") != "forest_pool":
            raise ValueError(f"not a ForestPool snapshot: {state.get('kind')!r}")
        pool = cls(min_class=state["min_class"], m=state["m"],
                   init_rows=state["init_rows"], policy=state["policy"])
        pool.quarantined = {tuple(k) for k in state["quarantined"]}

        def load_common(ar: _Arena, d: dict) -> None:
            ar.rows = int(d["rows"])
            ar.n_true = np.asarray(d["n_true"], np.int64).copy()
            ar.versions = np.asarray(d["versions"], np.int64).copy()
            ar.free = [int(r) for r in d["free"]]
            ar.raw = {int(r): np.asarray(v, np.float64)
                      for r, v in d["raw"].items()}
            ar.builds, ar.grows = int(d["builds"]), int(d["grows"])

        for size, d in state["classes"].items():
            sc = _SizeClass(int(d["size"]), int(d["m"]), 1)
            load_common(sc, d)
            sc.degenerate_rows = {int(r) for r in d["degenerate_rows"]}
            sc.delta_rebuilds = int(d["delta_rebuilds"])
            sc.delta_skips = int(d["delta_skips"])
            sc.forest = (None if d["forest"] is None else
                         BatchedForest(*(jnp.asarray(x) for x in d["forest"])))
            pool.classes[int(size)] = sc
        for size, d in state["alias_classes"].items():
            ar = AliasArena(int(d["size"]), 1)
            load_common(ar, d)
            ar.rebuilds, ar.skips = int(d["rebuilds"]), int(d["skips"])
            ar.table = (None if d["table"] is None else
                        BatchedAlias(*(jnp.asarray(x) for x in d["table"])))
            pool.alias_classes[int(size)] = ar
        return pool

    # ------------------------------------------------------------- sampling

    def _drain_plan(self, handles) -> dict[tuple[str, int], list[int]]:
        """Validate handles and group draw indices by (method, size class)
        — each group is one batched kernel launch."""
        for h in set(handles):  # validate each distinct handle once
            self._check(h)
        by_group: dict[tuple[str, int], list[int]] = {}
        for q, h in enumerate(handles):
            by_group.setdefault((h.method, h.size_class), []).append(q)
        return by_group

    def _class_lanes(self, handles, qs) -> tuple[np.ndarray, int]:
        """Per-group lane rows, sentinel-padded (-1) to a pow2 bucket: the
        padding must never route into row 0 — after an evict that row holds
        a freed tenant's stale arrays (forest: fallback-cleared tied chains
        deeper than the kernel's fixed trip count; alias: zeroed table)."""
        qpad = _pow2_at_least(len(qs), 64)  # bucket the drain size too
        didp = np.full(qpad, -1, np.int32)
        didp[: len(qs)] = [handles[q].row for q in qs]
        return didp, qpad

    def _guard_group(self, meth: str, size: int, rows) -> None:
        """Drain-time invariant screen (``guard=True``): before launching a
        group's kernel, vectorized-check the rows it will touch — forest
        rows must hold a finite monotone [0, 1] CDF, alias rows a valid
        split/target table. Catches payload corruption that slipped past
        admission (e.g. a bug writing through a freed row) at the cost the
        ``pool_sampling,guard=on`` bench row witnesses."""
        ridx = np.unique(np.asarray(rows, np.int64))
        ridx = ridx[ridx >= 0]
        if ridx.size == 0:
            return
        if meth == "alias":
            ar = self.alias_classes[size]
            q = np.asarray(ar.table.q)[ridx]
            a = np.asarray(ar.table.alias)[ridx]
            ok = (np.isfinite(q).all() and (q >= 0.0).all()
                  and (q <= 1.0).all() and (a >= 0).all()
                  and (a < ar.size).all())
            if not ok:
                raise ValueError(
                    f"guard: corrupted alias row(s) in size class {size}"
                )
        else:
            sc = self.classes[size]
            cdf = np.asarray(sc.forest.cdf)[ridx]
            ok = (np.isfinite(cdf).all()
                  and (np.diff(cdf, axis=1) >= 0.0).all()
                  and (cdf[:, 0] == 0.0).all() and (cdf[:, -1] == 1.0).all())
            if not ok:
                raise ValueError(
                    f"guard: corrupted forest row(s) in size class {size}"
                )

    def _clip_out(self, out, handles, qs, idx) -> None:
        hi = np.asarray([handles[q].n - 1 for q in qs], np.int64)
        out[qs] = np.minimum(np.asarray(idx)[: len(qs)], hi).astype(np.int32)

    def sample(self, handles, xi, use_pallas: bool = True,
               coalesce: bool = True, guard: bool = False) -> np.ndarray:
        """Bulk mixed-batch drain from host uniforms: draw q resolves
        ``xi[q]`` in ``handles[q]``'s distribution. One batched kernel
        launch per touched (method, size class) group — forest groups
        descend ``forest_sample_batched``, alias groups take the O(1)
        ``alias_sample_batched`` path (the whole point: a thousand tenants
        over 3 classes is 3 launches, not 1000). Results are clipped to
        each tenant's true range (zero-width padded intervals / q==0
        padded cells are unreachable). Returns (Q,) int32 row-local
        indices. QMC serving should prefer :meth:`sample_streams`; this is
        the oracle/compat path and the natural PRNG entry point."""
        xi = np.asarray(xi, np.float32)
        if len(handles) != len(xi):
            raise ValueError("handles and xi must align elementwise")
        out = np.empty(len(xi), np.int32)
        for (meth, size), qs in self._drain_plan(handles).items():
            didp, qpad = self._class_lanes(handles, qs)
            if guard:
                self._guard_group(meth, size, didp)
            up = np.pad(xi[qs], (0, qpad - len(qs)))
            if meth == "alias":
                ar = self.alias_classes[size]
                idx = ops.alias_sample_batched(
                    ar.table, jnp.asarray(didp), jnp.asarray(up),
                    use_pallas=use_pallas, coalesce=coalesce,
                )
            else:
                sc = self.classes[size]
                idx = ops.forest_sample_batched(
                    sc.forest, jnp.asarray(didp), jnp.asarray(up),
                    use_pallas=use_pallas, coalesce=coalesce,
                    # host-side flag bookkeeping spares the drain a device sync
                    degenerate=bool(sc.degenerate_rows),
                )
            self._clip_out(out, handles, qs, idx)
        return out

    def sample_streams(self, handles, slots, streams,
                       use_pallas: bool = True, coalesce: bool = True,
                       return_xi: bool = False,
                       guard: bool = False) -> np.ndarray:
        """The stream-aware bulk drain: draw q resolves ``slots[q]``'s next
        QMC stream point in ``handles[q]``'s distribution, with the whole
        stream side on device. ``streams`` follows the ``DeviceQmcStreams``
        protocol: ``draw(slots)`` ranks duplicate slots, advances the
        per-slot counters (functionally, device-side), and hands back the
        per-lane rank-adjusted counters + offset bits; each touched forest
        group then runs ONE ``forest_sample_batched_streams`` launch that
        recomputes the points in-kernel and walks coalesced per-tree tiles.
        Alias groups (legal, but they forfeit the stratification the
        streams exist for — serving's ``auto`` method keeps QMC tenants on
        the forest path) consume the pre-pass points through ONE
        ``alias_sample_batched`` launch. Zero host-side counter mutation
        anywhere on this path. With ``return_xi`` also returns the (Q,)
        float32 points that were drawn (bit-equal to the host
        ``QmcStreams`` oracle — differential tests)."""
        slots = np.asarray(slots)
        if len(handles) != len(slots):
            raise ValueError("handles and slots must align elementwise")
        ctr, off, xi = streams.draw(slots)
        out = np.empty(len(slots), np.int32)
        for (meth, size), qs in self._drain_plan(handles).items():
            didp, qpad = self._class_lanes(handles, qs)
            if guard:
                self._guard_group(meth, size, didp)
            sel = jnp.asarray(qs, jnp.int32)
            pad = qpad - len(qs)
            if meth == "alias":
                ar = self.alias_classes[size]
                up = jnp.pad(jnp.asarray(xi)[sel], (0, pad))
                idx = ops.alias_sample_batched(
                    ar.table, jnp.asarray(didp), up,
                    use_pallas=use_pallas, coalesce=coalesce,
                )
                self._clip_out(out, handles, qs, idx)
                continue
            sc = self.classes[size]
            ctrp = jnp.pad(ctr[sel], (0, pad))
            offp = jnp.pad(off[sel], (0, pad))
            idx, _ = ops.forest_sample_batched_streams(
                sc.forest, jnp.asarray(didp), ctrp, offp,
                use_pallas=use_pallas, coalesce=coalesce,
                degenerate=bool(sc.degenerate_rows),
            )
            self._clip_out(out, handles, qs, idx)
        if return_xi:
            return out, np.asarray(xi)
        return out

    # ---------------------------------------------------------- inspection

    def forest_row(self, handle: Handle) -> RadixForest:
        """The tenant's padded forest as a single-distribution view
        (differential tests; serving should drain through :meth:`sample`)."""
        if handle.method != "forest":
            raise ValueError(
                f"handle method is {handle.method!r}; use alias_row"
            )
        sc = self._check(handle)
        return sc.forest.row(handle.row)

    def alias_row(self, handle: Handle) -> AliasTable:
        """The tenant's padded packed alias table as a single-distribution
        view (differential tests; serving drains through :meth:`sample`)."""
        if handle.method != "alias":
            raise ValueError(
                f"handle method is {handle.method!r}; use forest_row"
            )
        ar = self._check(handle)
        return ar.table.row(handle.row)

    def weights(self, handle: Handle) -> np.ndarray:
        """Normalized float32 weights currently served for the tenant.
        Quarantined handles refuse (:class:`QuarantinedError`) — the row
        serves a uniform placeholder, not the tenant's submission, and
        reading it back as if it were theirs would hide the quarantine."""
        sc = self._check(handle)
        if self._qkey(handle) in self.quarantined:
            raise QuarantinedError(
                f"handle is quarantined (serving uniform placeholder): {handle}"
            )
        return normalize_weights(sc.raw[handle.row])

    def stats(self) -> dict:
        """Per-class occupancy/build counters + pool-level tenant count
        (both methods; ``classes`` is the forest side, ``alias_classes``
        the packed-alias side)."""
        per = {
            size: dict(
                m=sc.m, rows=sc.rows, occupied=sc.occupied,
                free=len(sc.free), builds=sc.builds,
                delta_rebuilds=sc.delta_rebuilds,
                delta_skips=sc.delta_skips, grows=sc.grows,
            )
            for size, sc in sorted(self.classes.items())
        }
        aper = {
            size: dict(
                rows=ar.rows, occupied=ar.occupied, free=len(ar.free),
                builds=ar.builds, rebuilds=ar.rebuilds, skips=ar.skips,
                grows=ar.grows,
            )
            for size, ar in sorted(self.alias_classes.items())
        }
        return dict(
            classes=per,
            alias_classes=aper,
            tenants=sum(sc.occupied for sc in self.classes.values())
            + sum(ar.occupied for ar in self.alias_classes.values()),
            policy=self.policy,
            quarantined=len(self.quarantined),
        )


@functools.partial(jax.jit, static_argnames=("m",))
def _rebuild_row(cdf: jax.Array, d: jax.Array, m: int) -> RadixForest:
    """Jitted single-row rebuild from a CDF + precomputed distances (one
    compiled program per size class, shared by every tenant update)."""
    return forest_from_cdf(cdf, m, d=d)
