"""Size-class forest arenas: many variable-n tenants, few compiled programs.

The multi-tenant serving problem: thousands of clients each own a *small*
categorical of a *different* size, churning (insert / re-weight / evict) at
request rate. Naively that is one compiled build + one compiled sampler per
distinct ``n`` — a recompile storm. :class:`ForestPool` packs tenants into
**power-of-two size classes** (weights zero-padded to the class size, guide
resolution fixed per class), so every tenant in a class shares the same
stacked :class:`~repro.pool.batched.BatchedForest` arrays and the same
handful of compiled programs: one fused batched build per (rows, size), one
batched sampling launch per (size, batch) — regardless of how many tenants
come and go.

Slot lifecycle: :meth:`ForestPool.insert` hands out a stable
:class:`Handle` (size class, row, true ``n``, version). Rows are recycled
through a **free list**; every recycle bumps the row's **version counter**,
so a stale handle (evicted tenant, reused slot) raises instead of silently
sampling someone else's distribution. :meth:`ForestPool.update_weights`
re-targets a tenant in place, routing the Algorithm-1 re-work through
:mod:`repro.kernels.forest_delta`: a bit-identical CDF skips the rebuild
entirely, otherwise the new separator distances feed a single-row rebuild
scattered back into the stack.

Zero-padding is sound by the paper's own semantics: padded intervals have
zero width, so no uniform in [0, 1) ever resolves to one (boundary hits are
measure-zero and clipped to the tenant's true range on the way out).

Draining comes in two flavors. :meth:`ForestPool.sample` takes host
uniforms (the differential oracle path). :meth:`ForestPool.sample_streams`
is the serving hot path: it takes per-draw *slot ids* plus a device-side
QMC stream object (``DeviceQmcStreams`` protocol: ``draw(slots) -> (ctr,
offset_bits, xi)``), ranks duplicate slots and advances every counter in
one jitted pre-pass, then resolves each touched size class with a single
coalesced ``forest_sample_batched_streams`` launch whose kernel computes
the stream points in-kernel — a full mixed-size-class drain mutates no
host-side counter state at all. Both flavors pad drain lanes to
power-of-two bucket sizes with **sentinel** dist ids (``-1``): a sentinel
lane resolves to a no-op instead of descending row 0's tree, which after an
evict holds a freed tenant's stale (fallback-cleared) arrays.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cdf import (
    build_cdf,
    lower_bounds,
    normalize_weights,
    updated_weights,
)
from repro.core.forest import RadixForest, forest_from_cdf
from repro.kernels import ops

from .batched import BatchedForest, build_forest_batched


class Handle(NamedTuple):
    """Stable tenant reference: which class/row, how big, which lifetime."""

    size_class: int  # padded n (power of two) — the class key
    row: int         # row in the class's stacked arrays
    n: int           # true (unpadded) distribution size
    version: int     # row lifetime counter; mismatch => stale handle


def _pow2_at_least(x: int, floor: int) -> int:
    p = max(int(floor), 1)
    while p < x:
        p <<= 1
    return p


class _SizeClass:
    """One stacked arena: all tenants padded to ``size`` leaves."""

    def __init__(self, size: int, m: int, init_rows: int):
        self.size = size
        self.m = m
        self.rows = init_rows
        self.forest: BatchedForest | None = None  # allocated on first build
        self.n_true = np.zeros(init_rows, np.int64)
        self.versions = np.zeros(init_rows, np.int64)
        self.free: list[int] = list(range(init_rows - 1, -1, -1))
        self.raw: dict[int, np.ndarray] = {}  # row -> float64 raw weights
        self.degenerate_rows: set[int] = set()  # rows with flagged cells
        self.builds = 0
        self.delta_rebuilds = 0
        self.delta_skips = 0
        self.grows = 0

    @property
    def occupied(self) -> int:
        return self.rows - len(self.free)


def _zeros_forest(rows: int, n: int, m: int) -> BatchedForest:
    """Placeholder stack for never-occupied rows (no draw ever routes to a
    row without a live handle, so content only needs valid shapes/dtypes)."""
    return BatchedForest(
        cdf=jnp.zeros((rows, n + 1), jnp.float32),
        table=jnp.zeros((rows, m), jnp.int32),
        left=jnp.zeros((rows, n), jnp.int32),
        right=jnp.zeros((rows, n), jnp.int32),
        cell_first=jnp.zeros((rows, m + 1), jnp.int32),
        fallback=jnp.zeros((rows, m), jnp.bool_),
    )


class ForestPool:
    """A batched radix-forest pool over power-of-two size-class arenas.

    Parameters: ``min_class`` floors the smallest padded size (tiny tenants
    share one class instead of one class per n); ``m`` pins one guide
    resolution for every class (default: each class uses ``m = size``, the
    repo-wide guide density); ``init_rows`` is the starting arena height,
    doubled on demand.
    """

    def __init__(self, min_class: int = 8, m: int | None = None,
                 init_rows: int = 4):
        if min_class < 1 or (min_class & (min_class - 1)):
            raise ValueError("min_class must be a positive power of two")
        self.min_class = min_class
        self._m = m
        self.init_rows = max(int(init_rows), 1)
        self.classes: dict[int, _SizeClass] = {}

    # ------------------------------------------------------------- plumbing

    def _class_for(self, n: int) -> _SizeClass:
        size = _pow2_at_least(n, self.min_class)
        sc = self.classes.get(size)
        if sc is None:
            sc = _SizeClass(size, self._m or size, self.init_rows)
            self.classes[size] = sc
        return sc

    def _check(self, h: Handle) -> _SizeClass:
        # O(1): ``raw`` holds exactly the occupied rows (insert sets, evict
        # pops), and evict bumps the version BEFORE freeing, so a recycled
        # row can never satisfy a stale handle's version.
        sc = self.classes.get(h.size_class)
        if (
            sc is None
            or h.row not in sc.raw
            or sc.versions[h.row] != h.version
        ):
            raise ValueError(f"stale or evicted handle: {h}")
        return sc

    def _grow(self, sc: _SizeClass) -> None:
        extra = sc.rows
        sc.free.extend(range(sc.rows + extra - 1, sc.rows - 1, -1))
        pad = _zeros_forest(extra, sc.size, sc.m)
        if sc.forest is not None:
            sc.forest = BatchedForest(
                *(jnp.concatenate([a, b]) for a, b in zip(sc.forest, pad))
            )
        sc.n_true = np.concatenate([sc.n_true, np.zeros(extra, np.int64)])
        sc.versions = np.concatenate([sc.versions, np.zeros(extra, np.int64)])
        sc.rows += extra
        sc.grows += 1

    def _take_row(self, sc: _SizeClass) -> int:
        if not sc.free:
            self._grow(sc)
        return sc.free.pop()

    def _pad(self, w: np.ndarray, size: int) -> np.ndarray:
        return np.pad(w.astype(np.float32), (0, size - len(w)))

    def _write_rows(self, sc: _SizeClass, rows: list[int],
                    built: BatchedForest) -> None:
        if sc.forest is None:
            sc.forest = _zeros_forest(sc.rows, sc.size, sc.m)
        idx = jnp.asarray(rows, jnp.int32)
        sc.forest = BatchedForest(
            *(a.at[idx].set(b) for a, b in zip(sc.forest, built))
        )

    # ------------------------------------------------------------ lifecycle

    def insert(self, weights) -> Handle:
        """Admit one tenant; see :meth:`insert_many` for the fused path."""
        return self.insert_many([weights])[0]

    def insert_many(self, weights_list) -> list[Handle]:
        """Admit a group of tenants, fusing each size class's builds into
        ONE batched launch (``build_forest_batched`` over the stacked padded
        rows) — the build-B-at-once path the pool exists for. The group is
        padded to a power-of-two batch so heterogeneous admission waves
        reuse a logarithmic number of compiled build programs."""
        raws = [np.asarray(w, np.float64) for w in weights_list]
        norms = [normalize_weights(r) for r in raws]
        handles: list[Handle | None] = [None] * len(raws)
        by_class: dict[int, list[int]] = {}
        for i, w in enumerate(norms):
            sc = self._class_for(len(w))
            by_class.setdefault(sc.size, []).append(i)
        for size, idxs in by_class.items():
            sc = self.classes[size]
            rows = [self._take_row(sc) for _ in idxs]
            stack = np.stack([self._pad(norms[i], size) for i in idxs])
            bpad = _pow2_at_least(len(idxs), 1)
            if bpad != len(idxs):  # dummy rows keep the program count low
                fill = np.full((bpad - len(idxs), size), 1.0, np.float32)
                stack = np.concatenate([stack, fill])
            built = build_forest_batched(jnp.asarray(stack), sc.m)
            built = BatchedForest(*(a[: len(idxs)] for a in built))
            self._write_rows(sc, rows, built)
            sc.builds += len(idxs)
            # one sync per admission wave keeps the drain path sync-free
            flagged = np.asarray(built.fallback.any(axis=1))
            for (i, row), flag in zip(zip(idxs, rows), flagged):
                sc.n_true[row] = len(norms[i])
                sc.raw[row] = raws[i]
                if flag:
                    sc.degenerate_rows.add(row)
                handles[i] = Handle(size, row, len(norms[i]), int(sc.versions[row]))
        return handles  # type: ignore[return-value]

    def update_weights(self, handle: Handle, weights=None, *, delta=None) -> None:
        """In-place re-target of one tenant (full weights or a delta on the
        raw weights). The Algorithm-1 re-work routes through
        :func:`repro.kernels.ops.forest_delta_update`: bit-unchanged CDFs
        skip the rebuild; otherwise the returned separator distances feed a
        single-row rebuild. The handle stays valid (versions track slot
        reuse, not content)."""
        sc = self._check(handle)
        for name, arr in (("weights", weights), ("delta", delta)):
            if arr is not None and np.asarray(arr).shape != (handle.n,):
                raise ValueError(
                    f"update keeps n fixed: handle has n={handle.n}, got "
                    f"{name} of shape {np.asarray(arr).shape} (scalars and "
                    f"padded-size arrays would silently broadcast)"
                )
        raw, w = updated_weights(sc.raw[handle.row], weights, delta=delta)
        sc.raw[handle.row] = raw
        new_cdf = build_cdf(jnp.asarray(self._pad(w, sc.size)))
        old_cdf = sc.forest.cdf[handle.row]
        # Skip keyed on raw CDF bits (the dist-layer policy): the clamped
        # lower bounds alone could hide a cdf move inside the last-ulp-
        # below-1 region and leave a stale row serving.
        if np.array_equal(
            np.asarray(old_cdf).view(np.uint32),
            np.asarray(new_cdf).view(np.uint32),
        ):
            sc.delta_skips += 1
            return
        d_new, _ = ops.forest_delta_update(
            lower_bounds(old_cdf), lower_bounds(new_cdf), sc.m,
            use_pallas=ops.use_pallas_default(),
        )
        built = _rebuild_row(new_cdf, d_new, sc.m)
        self._write_rows(sc, [handle.row], BatchedForest(
            *(a[None] for a in built)
        ))
        if bool(jax.device_get(built.fallback.any())):
            sc.degenerate_rows.add(handle.row)
        else:
            sc.degenerate_rows.discard(handle.row)
        sc.delta_rebuilds += 1

    def evict(self, handle: Handle) -> None:
        """Release the tenant's row back to the class free list. The version
        bump invalidates every outstanding handle to the row. The row's
        fallback bits are cleared so a dead degenerate (tied-weight) tenant
        stops forcing the side-table pre-resolution path on the whole
        class's future drains (``ops.forest_sample_batched`` keys that path
        off ``fallback.any()`` over the stack)."""
        sc = self._check(handle)
        sc.versions[handle.row] += 1
        sc.n_true[handle.row] = 0
        sc.raw.pop(handle.row, None)
        sc.free.append(handle.row)
        if handle.row in sc.degenerate_rows:
            sc.degenerate_rows.discard(handle.row)
            sc.forest = sc.forest._replace(
                fallback=sc.forest.fallback.at[handle.row].set(False)
            )

    # ------------------------------------------------------------- sampling

    def _drain_plan(self, handles) -> dict[int, list[int]]:
        """Validate handles and group draw indices by touched size class."""
        for h in set(handles):  # validate each distinct handle once
            self._check(h)
        by_class: dict[int, list[int]] = {}
        for q, h in enumerate(handles):
            by_class.setdefault(h.size_class, []).append(q)
        return by_class

    def _class_lanes(self, handles, qs) -> tuple[np.ndarray, int]:
        """Per-class lane rows, sentinel-padded (-1) to a pow2 bucket: the
        padding must never route into row 0 — after an evict that row holds
        a freed tenant's stale (fallback-cleared) arrays, whose tied chains
        can run deeper than the kernel's fixed trip count."""
        qpad = _pow2_at_least(len(qs), 64)  # bucket the drain size too
        didp = np.full(qpad, -1, np.int32)
        didp[: len(qs)] = [handles[q].row for q in qs]
        return didp, qpad

    def _clip_out(self, out, handles, qs, idx) -> None:
        hi = np.asarray([handles[q].n - 1 for q in qs], np.int64)
        out[qs] = np.minimum(np.asarray(idx)[: len(qs)], hi).astype(np.int32)

    def sample(self, handles, xi, use_pallas: bool = True,
               coalesce: bool = True) -> np.ndarray:
        """Bulk mixed-batch drain from host uniforms: draw q resolves
        ``xi[q]`` in ``handles[q]``'s distribution. One
        ``forest_sample_batched`` launch per touched size class (the whole
        point: a thousand tenants over 3 classes is 3 launches, not 1000).
        Results are clipped to each tenant's true range (zero-width padded
        intervals are measure-zero boundary hits). Returns (Q,) int32
        row-local interval indices. Serving should prefer
        :meth:`sample_streams`; this is the oracle/compat path."""
        xi = np.asarray(xi, np.float32)
        if len(handles) != len(xi):
            raise ValueError("handles and xi must align elementwise")
        out = np.empty(len(xi), np.int32)
        for size, qs in self._drain_plan(handles).items():
            sc = self.classes[size]
            didp, qpad = self._class_lanes(handles, qs)
            up = np.pad(xi[qs], (0, qpad - len(qs)))
            idx = ops.forest_sample_batched(
                sc.forest, jnp.asarray(didp), jnp.asarray(up),
                use_pallas=use_pallas, coalesce=coalesce,
                # host-side flag bookkeeping spares the drain a device sync
                degenerate=bool(sc.degenerate_rows),
            )
            self._clip_out(out, handles, qs, idx)
        return out

    def sample_streams(self, handles, slots, streams,
                       use_pallas: bool = True, coalesce: bool = True,
                       return_xi: bool = False) -> np.ndarray:
        """The stream-aware bulk drain: draw q resolves ``slots[q]``'s next
        QMC stream point in ``handles[q]``'s distribution, with the whole
        stream side on device. ``streams`` follows the ``DeviceQmcStreams``
        protocol: ``draw(slots)`` ranks duplicate slots, advances the
        per-slot counters (functionally, device-side), and hands back the
        per-lane rank-adjusted counters + offset bits; each touched size
        class then runs ONE ``forest_sample_batched_streams`` launch that
        recomputes the points in-kernel and walks coalesced per-tree tiles.
        Zero host-side counter mutation anywhere on this path. With
        ``return_xi`` also returns the (Q,) float32 points that were drawn
        (bit-equal to the host ``QmcStreams`` oracle — differential tests).
        """
        slots = np.asarray(slots)
        if len(handles) != len(slots):
            raise ValueError("handles and slots must align elementwise")
        ctr, off, xi = streams.draw(slots)
        out = np.empty(len(slots), np.int32)
        for size, qs in self._drain_plan(handles).items():
            sc = self.classes[size]
            didp, qpad = self._class_lanes(handles, qs)
            sel = jnp.asarray(qs, jnp.int32)
            pad = qpad - len(qs)
            ctrp = jnp.pad(ctr[sel], (0, pad))
            offp = jnp.pad(off[sel], (0, pad))
            idx, _ = ops.forest_sample_batched_streams(
                sc.forest, jnp.asarray(didp), ctrp, offp,
                use_pallas=use_pallas, coalesce=coalesce,
                degenerate=bool(sc.degenerate_rows),
            )
            self._clip_out(out, handles, qs, idx)
        if return_xi:
            return out, np.asarray(xi)
        return out

    # ---------------------------------------------------------- inspection

    def forest_row(self, handle: Handle) -> RadixForest:
        """The tenant's padded forest as a single-distribution view
        (differential tests; serving should drain through :meth:`sample`)."""
        sc = self._check(handle)
        return sc.forest.row(handle.row)

    def weights(self, handle: Handle) -> np.ndarray:
        """Normalized float32 weights currently served for the tenant."""
        sc = self._check(handle)
        return normalize_weights(sc.raw[handle.row])

    def stats(self) -> dict:
        """Per-class occupancy/build counters + pool-level program count."""
        per = {
            size: dict(
                m=sc.m, rows=sc.rows, occupied=sc.occupied,
                free=len(sc.free), builds=sc.builds,
                delta_rebuilds=sc.delta_rebuilds,
                delta_skips=sc.delta_skips, grows=sc.grows,
            )
            for size, sc in sorted(self.classes.items())
        }
        return dict(
            classes=per,
            tenants=sum(sc.occupied for sc in self.classes.values()),
        )


@functools.partial(jax.jit, static_argnames=("m",))
def _rebuild_row(cdf: jax.Array, d: jax.Array, m: int) -> RadixForest:
    """Jitted single-row rebuild from a CDF + precomputed distances (one
    compiled program per size class, shared by every tenant update)."""
    return forest_from_cdf(cdf, m, d=d)
