"""Batched sampling pools: fused multi-distribution construction (radix
forests and packed alias tables), size-class arenas, and bulk mixed-batch
sampling for multi-tenant serving — with the sampling method (monotone
forest vs O(1) alias) a per-tenant attribute."""
from .arena import AliasArena, ForestPool, Handle
from .batched import (
    BatchedAlias,
    BatchedForest,
    batched_from_row_forest,
    build_alias_batched,
    build_forest_batched,
    build_forest_batched_from_cdf,
    sample_alias_batched,
    sample_forest_batched,
)

__all__ = [
    "AliasArena",
    "BatchedAlias",
    "BatchedForest",
    "ForestPool",
    "Handle",
    "batched_from_row_forest",
    "build_alias_batched",
    "build_forest_batched",
    "build_forest_batched_from_cdf",
    "sample_alias_batched",
    "sample_forest_batched",
]
