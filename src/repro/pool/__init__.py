"""Batched radix-forest pools: fused multi-distribution construction,
size-class arenas, and bulk mixed-batch sampling for multi-tenant serving."""
from .arena import ForestPool, Handle
from .batched import (
    BatchedForest,
    build_forest_batched,
    build_forest_batched_from_cdf,
    sample_forest_batched,
)

__all__ = [
    "BatchedForest",
    "ForestPool",
    "Handle",
    "build_forest_batched",
    "build_forest_batched_from_cdf",
    "sample_forest_batched",
]
