import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Per-op collective breakdown for one cell: the §Perf microscope.

  PYTHONPATH=src python -m repro.launch.inspect_collectives --arch granite-3-8b --shape train_4k
"""
import argparse
import re

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--gather-weights", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--decode-2d", action="store_true")
    ap.add_argument("--remat", default="dots")
    args = ap.parse_args()

    import repro.configs as configs
    from repro.launch import roofline as R
    from repro.launch.dryrun import run_cell  # noqa: F401 (env already set)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES

    # Re-lower directly to keep the compiled object.
    import dataclasses
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist.sharding import Policy, batch_specs, cache_spec_tree, param_shardings
    from repro.launch.shapes import batch_specs_struct, decode_inputs_struct, params_struct
    from repro.train.optimizer import AdamWConfig, init_opt
    from repro.train.step import make_serve_step, make_train_step

    arch = configs.canonical(args.arch)
    cfg = configs.get(arch)
    sh = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    pol = Policy.for_mesh(mesh, sh.kind)
    if args.no_fsdp:
        pol = dataclasses.replace(pol, fsdp=())
    if args.decode_2d:
        pol = dataclasses.replace(pol, dp=(), fsdp=(), tp=("data", "model"), shard_seq=True)
    import contextlib
    from repro.dist.hints import Hints, sharding_hints
    hint_ctx = (sharding_hints(Hints(pol, gather_weights=args.gather_weights,
                                     seq_shard=args.seq_shard))
                if (args.gather_weights or args.seq_shard) else contextlib.nullcontext())
    p_sds = params_struct(cfg)
    p_shard = param_shardings(mesh, p_sds, pol)
    with mesh, hint_ctx:
        if sh.kind == "train":
            oc = AdamWConfig()
            o_sds = jax.eval_shape(lambda p: init_opt(oc, p), p_sds)
            o_shard = type(o_sds)(
                step=NamedSharding(mesh, P()),
                m=param_shardings(mesh, o_sds.m, pol),
                v=param_shardings(mesh, o_sds.v, pol),
            )
            b_sds = batch_specs_struct(cfg, sh)
            b_shard = {k: NamedSharding(mesh, s) for k, s in batch_specs(cfg, pol).items()}
            step = make_train_step(cfg, oc, remat=args.remat)
            compiled = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                               donate_argnums=(0, 1)).lower(p_sds, o_sds, b_sds).compile()
            hints = (cfg.n_periods,)
        else:
            d = decode_inputs_struct(cfg, sh)
            c_shard = cache_spec_tree(cfg, d["cache"], pol, mesh)
            dp = None if pol.shard_seq else (pol.dp if len(pol.dp) > 1 else pol.dp[0])
            tok_spec = P(dp, None, None) if cfg.frontend == "embed" else P(dp)
            in_sh = [p_shard, c_shard, NamedSharding(mesh, tok_spec),
                     NamedSharding(mesh, P(dp)), NamedSharding(mesh, P(dp))]
            argsl = [p_sds, d["cache"], d["token"], d["pos"], d["xi"]]
            if cfg.encoder_layers:
                in_sh.append(NamedSharding(mesh, P(dp, None, None)))
                argsl.append(d["enc_out"])
            step = make_serve_step(cfg, use_pallas=False)
            compiled = jax.jit(step, in_shardings=tuple(in_sh),
                               donate_argnums=(1,)).lower(*argsl).compile()
            hints = (cfg.n_periods,)

        txt = compiled.as_text()
        rows = []
        for line in txt.splitlines():
            ls = line.strip()
            m = re.search(r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+([\w-]+?)(-start)?\(", ls)
            if not m or m.group(2) not in R._COLL_KINDS:
                continue
            shapes = R._SHAPE_RE.findall(m.group(1))
            rbytes = sum(R._shape_bytes(f"{dt}[{dims}]") for dt, dims in shapes)
            if m.group(3) and len(shapes) >= 2:
                rbytes //= 2
            mo = re.search(r'op_name="([^"]*)"', ls)
            name = mo.group(1) if mo else "?"
            depth = name.count("/while/")
            mult = int(np.prod([hints[d] if d < len(hints) else 1 for d in range(depth)])) if depth else 1
            rows.append((rbytes * mult, rbytes, mult, m.group(2), m.group(1)[:40], name[-90:]))
        rows.sort(reverse=True)
        total = sum(r[0] for r in rows)
        print(f"total effective per-device collective result bytes: {total/1e9:.1f} GB")
        for eff, raw, mult, kind, shape, name in rows[: args.top]:
            print(f"{eff/1e9:9.2f}GB x{mult:3d} {kind:18s} {shape:40s} {name}")


if __name__ == "__main__":
    main()
