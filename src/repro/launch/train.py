"""Production training launcher.

On a real cluster every host runs this entry point under `jax.distributed`
(same SPMD program; checkpoints on shared storage give pod-failure recovery
via auto-resume, see repro/ckpt). On this container it runs the same loop on
the local device. Policy defaults to `Policy.recommended` (EXPERIMENTS §Perf
presets).

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --preset reduced --steps 50
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()

    import repro.configs as C
    from repro.train import TrainConfig, Trainer

    cfg = C.get(args.arch) if args.preset == "full" else C.get_reduced(args.arch)
    tc = TrainConfig(
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt or f"checkpoints/{C.canonical(args.arch)}_{args.preset}",
        remat=args.remat,
        microbatches=args.microbatches,
    )
    out = Trainer(cfg, tc).run()
    print(f"done: final loss {out['final_loss']}")


if __name__ == "__main__":
    main()
