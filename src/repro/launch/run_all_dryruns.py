"""Run every (arch x shape x mesh) dry-run cell in an isolated subprocess
(device-count env must precede jax init; also isolates compile memory).

  PYTHONPATH=src python -m repro.launch.run_all_dryruns [--multi-pod-only]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", default="pod1,pod2")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    from repro.launch.shapes import cell_matrix  # no jax device init here

    Path(args.outdir).mkdir(parents=True, exist_ok=True)
    results = []
    for mesh in args.meshes.split(","):
        multi = mesh == "pod2"
        for arch, shape, status in cell_matrix():
            out = Path(args.outdir) / f"{arch}__{shape}__{mesh}.json"
            if status != "run":
                out.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "status": "skipped", "reason": status,
                }, indent=2))
                print(f"SKIP  {arch:28s} {shape:12s} {mesh}: {status}")
                continue
            if args.skip_existing and out.exists():
                rec = json.loads(out.read_text())
                if rec.get("status") == "ok":
                    print(f"HAVE  {arch:28s} {shape:12s} {mesh}")
                    continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--out", str(out),
            ] + (["--multi-pod"] if multi else [])
            t0 = time.time()
            try:
                proc = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=args.timeout
                )
                ok = proc.returncode == 0
            except subprocess.TimeoutExpired:
                ok = False
                out.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "status": "error", "error": "timeout",
                }, indent=2))
            dt = time.time() - t0
            print(f"{'OK  ' if ok else 'FAIL'}  {arch:28s} {shape:12s} {mesh} "
                  f"({dt:.0f}s)", flush=True)
            if not ok and out.exists():
                rec = json.loads(out.read_text())
                print("      ", rec.get("error", "?")[:200])
            results.append((arch, shape, mesh, ok))
    bad = [r for r in results if not r[3]]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells OK; {len(bad)} failed")
    for b in bad:
        print("  FAILED:", b)


if __name__ == "__main__":
    main()
