"""Production mesh definition (function, not constant: importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 topology).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis carries
    data parallelism + FSDP (and optionally pipeline stages, see
    repro.dist.pipeline)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int = 1, model: int = 1):
    """Small mesh over host devices for tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set before jax init)."""
    return jax.make_mesh((n // model, model), ("data", "model"))
