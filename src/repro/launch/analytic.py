"""Analytic FLOP / HBM-byte model per (arch x shape) step.

Why this exists: XLA's HloCostAnalysis counts a while-loop body ONCE, so any
scanned-layer model under-reports flops/bytes by ~n_periods x in
``compiled.cost_analysis()``. The dry-run records both numbers; the roofline
terms use the analytic model (exact matmul counting from the known
architecture), with the HLO value kept as a cross-check for unscanned cells
(they agree within ~20% there — see EXPERIMENTS.md §Roofline notes).

Conventions: fwd matmul flops = 2*M*N*K; train = 3x fwd (bwd = 2x) for
remat='dots' (matmul outputs saved), 4x for remat='full'; attention scores
count the full (unmasked) S^2 matmul, as compiled.
"""
from __future__ import annotations

from repro.models.config import ModelConfig


def _attn_flops(cfg: ModelConfig, T: int, S_kv: int, cross_T: int = 0) -> float:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    f = 2 * T * D * (H * hd + 2 * KV * hd)          # qkv
    f += 2 * T * S_kv * H * hd * 2                  # scores + weighted sum
    f += 2 * T * H * hd * D                         # out proj
    if cross_T:
        f += 2 * T * D * H * hd + 2 * cross_T * D * 2 * KV * hd
        f += 2 * T * cross_T * H * hd * 2 + 2 * T * H * hd * D
    return f


def _dense_mlp_flops(cfg: ModelConfig, T: int) -> float:
    return 2 * T * cfg.d_model * cfg.d_ff * 3


def _moe_flops(cfg: ModelConfig, T: int) -> float:
    from repro.models.moe import GROUP_TOKENS, _pick_groups
    import numpy as np

    D, E, F, k = cfg.d_model, cfg.n_experts, cfg.expert_ff, cfg.top_k
    G = _pick_groups(T)
    g = T // G
    C = max(int(np.ceil(g * k / E * cfg.capacity_factor)), 1)
    f = 2 * T * D * E                                # router
    f += 2 * T * E * C * 2                           # one-hot bookkeeping (cheap)
    f += 2 * G * E * C * D * (2)                     # dispatch + combine gathers
    f += 2 * T * E * C * D                           # dispatch einsum (dense)
    f += 2 * G * E * C * D * F * 3                   # expert ffn
    f += 2 * T * E * C * D                           # combine einsum
    f += 2 * T * D * F * cfg.n_shared_experts * 3    # shared expert
    return f


def _mamba_flops(cfg: ModelConfig, T: int) -> float:
    D = cfg.d_model
    DI = cfg.ssm_expand * D
    N = cfg.ssm_state
    f = 2 * T * D * 2 * DI                           # in_proj
    f += 2 * T * DI * cfg.ssm_conv                   # conv
    f += 2 * T * DI * (2 * N + 1)                    # x_proj
    f += T * DI * N * 8                              # scan combine (assoc)
    f += 2 * T * DI * N                              # y readout
    f += 2 * T * DI * D                              # out_proj
    return f


def _mlstm_flops(cfg: ModelConfig, T: int) -> float:
    D = cfg.d_model
    DI = 2 * D
    H = cfg.n_heads
    hd = DI // H
    L = min(cfg.mlstm_chunk, max(T, 1))
    f = 2 * T * D * 2 * DI + 2 * T * DI * DI * 3 + 2 * T * DI * 2 * H
    f += 2 * T * L * DI * 3                          # intra qk / hv / n
    f += 2 * T * hd * DI * 2                         # inter readout
    f += (T / max(L, 1)) * H * hd * hd * 6           # chunk state update
    f += 2 * T * DI * D                              # down
    return f


def _slstm_flops(cfg: ModelConfig, T: int) -> float:
    D = cfg.d_model
    hd = D // cfg.n_heads
    f = 2 * T * D * 4 * D                            # wx
    f += 2 * T * D * 4 * hd                          # recurrent (block diag)
    f += 30 * T * D                                  # gates/state elementwise
    f += 2 * T * D * D                               # down
    return f


def step_flops(cfg: ModelConfig, kind: str, seq_len: int, batch: int,
               remat: str = "dots") -> dict[str, float]:
    """Global flops for one step of the given shape kind."""
    if kind in ("train", "prefill"):
        T = batch * seq_len
        S_kv = seq_len
    else:  # decode / long: one token, KV length seq_len
        T = batch
        S_kv = seq_len
    period = cfg.block_pattern
    fwd = 0.0
    for li in range(cfg.n_layers):
        b = period[li % len(period)]
        m = cfg.mlp_pattern[li % len(cfg.mlp_pattern)]
        if b == "attn":
            fwd += _attn_flops(
                cfg, T, S_kv, cross_T=batch * seq_len if cfg.cross_attention else 0
            )
        elif b == "mamba":
            fwd += _mamba_flops(cfg, T)
        elif b == "mlstm":
            fwd += _mlstm_flops(cfg, T)
        else:
            fwd += _slstm_flops(cfg, T)
        if m == "dense":
            fwd += _dense_mlp_flops(cfg, T)
        elif m == "moe":
            fwd += _moe_flops(cfg, T)
    # encoder (runs on the full frame sequence even at decode: enc_out given,
    # so only for train/prefill)
    if cfg.encoder_layers and kind in ("train", "prefill"):
        Te = batch * seq_len
        fwd += cfg.encoder_layers * (
            _attn_flops(cfg, Te, seq_len) + _dense_mlp_flops(cfg, Te)
        )
    fwd += 2 * T * cfg.d_model * cfg.vocab           # lm head
    mult = {"train": 4.0 if remat == "full" else 3.0}.get(kind, 1.0)
    return {"fwd_flops": fwd, "step_flops": fwd * mult}


def step_bytes(cfg: ModelConfig, kind: str, seq_len: int, batch: int,
               opt_bytes_per_param: int = 12) -> dict[str, float]:
    """Global HBM bytes for one step (optimistic fused estimate)."""
    total, _ = cfg.param_count()
    dt = 2 if cfg.dtype == "bfloat16" else 4
    if kind == "train":
        T = batch * seq_len
        pbytes = total * (2 * 4 + opt_bytes_per_param)   # fwd+bwd reads + opt
        act = cfg.n_layers * T * cfg.d_model * dt * 6    # save+read, coarse
        act += T * cfg.vocab * 4 * 2                     # logits fwd+bwd
        return {"step_bytes": pbytes + act}
    if kind == "prefill":
        T = batch * seq_len
        return {
            "step_bytes": total * dt
            + cfg.n_layers * T * cfg.d_model * dt * 2
            + T * cfg.vocab * 4 * 0 + batch * cfg.vocab * 4
        }
    # decode: every param + the whole cache is read per token
    cache = 0
    for li in range(cfg.n_layers):
        b = cfg.block_pattern[li % len(cfg.block_pattern)]
        if b == "attn":
            cache += batch * seq_len * cfg.n_kv_heads * cfg.hd * 2 * dt
        elif b == "mamba":
            cache += batch * cfg.ssm_expand * cfg.d_model * cfg.ssm_state * 4
        elif b == "mlstm":
            DI = 2 * cfg.d_model
            cache += batch * DI * (DI // cfg.n_heads) * 4
        else:
            cache += batch * cfg.d_model * 4 * 3
    return {"step_bytes": total * dt + cache + batch * cfg.vocab * 4}
