import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds ShapeDtypeStruct inputs (zero allocation), attaches
the sharding policy, runs ``jit(step).lower(...).compile()`` against the
production mesh, prints ``memory_analysis()`` / ``cost_analysis()``, derives
the three roofline terms, and writes a JSON record consumed by
EXPERIMENTS.md. Any sharding mismatch / unsupported collective here is a
real bug in the distribution config — that is the point of the exercise.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --list
"""
import argparse
import contextlib
import dataclasses
import json
import time
import traceback
from pathlib import Path

# Import hygiene: everything heavyweight (jax, repro.models, repro.dist, the
# step builders) is imported inside function bodies. Importing this module
# must stay cheap and dependency-free so `--list`, the report tooling, and
# `tests/test_imports.py` cannot be taken down by a broken subsystem.


def _opt_struct(params_sds, opt_dtype: str):
    import jax

    from repro.train.optimizer import AdamWConfig, init_opt

    oc = AdamWConfig(opt_dtype=opt_dtype)
    return jax.eval_shape(lambda p: init_opt(oc, p), params_sds), oc


def _dp(pol):
    return pol.dp if len(pol.dp) > 1 else (pol.dp[0] if pol.dp else None)


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool = False,
    opt_dtype: str = "float32",
    remat: str = "dots",
    microbatches: int = 1,
    policy_overrides: dict | None = None,
    donate: bool = True,
    gather_weights: bool = False,
    seq_shard: bool = False,
    params_dtype: str = "float32",
) -> dict:
    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    import repro.configs as configs
    from repro.dist.sharding import (
        Policy,
        batch_specs,
        cache_spec_tree,
        param_shardings,
    )
    from repro.launch import roofline as R
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import (
        SHAPES,
        batch_specs_struct,
        decode_inputs_struct,
        params_struct,
    )
    from repro.train.step import make_serve_step, make_train_step

    cfg = configs.get(arch)
    sh = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    if policy_overrides and policy_overrides.get("auto"):
        pol = Policy.recommended(cfg, mesh, sh.kind)
        # measured: gather-on-use pays for train only (refuted for prefill
        # at 70B and for small-model decode, see EXPERIMENTS §Perf)
        gather_weights = sh.kind == "train"
        seq_shard = pol.shard_seq
        policy_overrides = {k: v for k, v in policy_overrides.items() if k != "auto"}
        if policy_overrides:
            pol = dataclasses.replace(pol, **policy_overrides)
    else:
        pol = Policy.for_mesh(mesh, sh.kind)
        if policy_overrides:
            pol = dataclasses.replace(pol, **policy_overrides)

    rec: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": dict(mesh.shape),
        "chips": chips,
        "kind": sh.kind,
        "policy": dataclasses.asdict(pol),
        "opt_dtype": opt_dtype,
        "remat": remat,
        "microbatches": microbatches,
        "hints": {"gather_weights": gather_weights, "seq_shard": seq_shard},
    }
    from repro.dist.hints import Hints, sharding_hints

    hint_ctx = (
        sharding_hints(Hints(pol, gather_weights=gather_weights, seq_shard=seq_shard))
        if (gather_weights or seq_shard)
        else contextlib.nullcontext()
    )

    p_sds = params_struct(cfg)
    if params_dtype == "bfloat16":
        # pure-bf16 parameter variant (halves every gradient reduction and
        # the FSDP weight gathers; m/v stay in opt_dtype) — §Perf lever.
        import jax.numpy as jnp

        p_sds = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
            if l.dtype == jnp.float32 else l,
            p_sds,
        )
    rec["params_dtype"] = params_dtype
    p_shard = param_shardings(mesh, p_sds, pol)

    t0 = time.time()
    with mesh, hint_ctx:
        if sh.kind == "train":
            o_sds, oc = _opt_struct(p_sds, opt_dtype)
            # opt state shards like params; step counter replicated
            o_shard = type(o_sds)(
                step=NamedSharding(mesh, P()),
                m=param_shardings(mesh, o_sds.m, pol),
                v=param_shardings(mesh, o_sds.v, pol),
            )
            b_sds = batch_specs_struct(cfg, sh)
            b_shard = {
                k: NamedSharding(mesh, spec)
                for k, spec in batch_specs(cfg, pol, b_sds).items()
            }
            step = make_train_step(cfg, oc, remat=remat, microbatches=microbatches)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(p_sds, o_sds, b_sds)
        elif sh.kind == "prefill":
            from repro.train.step import make_prefill_step

            b_sds = batch_specs_struct(cfg, sh)
            b_shard = {
                k: NamedSharding(mesh, spec)
                for k, spec in batch_specs(cfg, pol, b_sds).items()
            }
            step = make_prefill_step(cfg, max_seq=sh.seq_len)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_sds, b_sds)
        else:  # decode / long
            d = decode_inputs_struct(cfg, sh)
            c_shard = cache_spec_tree(cfg, d["cache"], pol, mesh)
            dp = None if pol.shard_seq else _dp(pol)
            tok_spec = (
                P(dp, None, None) if cfg.frontend == "embed" else P(dp)
            )
            in_sh = [
                p_shard,
                c_shard,
                NamedSharding(mesh, tok_spec),
                NamedSharding(mesh, P(dp)),
                NamedSharding(mesh, P(dp)),
            ]
            args = [p_sds, d["cache"], d["token"], d["pos"], d["xi"]]
            if cfg.encoder_layers:
                in_sh.append(
                    NamedSharding(
                        mesh, P(dp, pol.sp if pol.shard_seq else None, None)
                    )
                )
                args.append(d["enc_out"])
            step = make_serve_step(cfg, use_pallas=False)
            jitted = jax.jit(
                step,
                in_shardings=tuple(in_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(*args)
        rec["lower_s"] = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1

        mem = compiled.memory_analysis()
        print("memory_analysis:", mem)
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        ca = compiled.cost_analysis()
        ca0 = ca[0] if isinstance(ca, (list, tuple)) else (ca or {})
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            float(ca0.get("flops", 0)), float(ca0.get("bytes accessed", 0))))

        # lax.scan lowers to while; HloCostAnalysis counts bodies once, so
        # supply analytic flops/bytes + trip hints (see launch/analytic.py).
        from repro.launch import analytic as A

        af = A.step_flops(cfg, sh.kind, sh.seq_len, sh.global_batch, remat)
        ab = A.step_bytes(
            cfg, sh.kind, sh.seq_len, sh.global_batch,
            opt_bytes_per_param=12 if opt_dtype == "float32" else 8,
        )
        hints = (
            (microbatches, cfg.n_periods) if microbatches > 1 else (cfg.n_periods,)
        )
        roof = R.analyze(
            compiled, mesh, chips,
            trip_hints=hints,
            analytic_flops=af["step_flops"],
            analytic_bytes=ab["step_bytes"],
        )
        rec["roofline"] = roof.to_dict()
        rec["analytic"] = {**af, **ab}
        tokens = sh.global_batch * (sh.seq_len if sh.kind in ("train", "prefill") else 1)
        mf = R.model_flops(cfg, tokens)
        rec.update(mf)
        useful = mf["model_flops_6NactiveD" if cfg.n_experts else "model_flops_6ND"]
        if sh.kind != "train":
            useful /= 3.0  # 6ND assumes fwd+bwd; fwd-only is 2ND
        rec["useful_flops"] = useful
        rec["useful_over_hlo"] = useful / max(roof.flops_global, 1.0)
        bound = max(roof.t_compute, roof.t_mem, roof.t_coll, roof.t_coll_wire)
        rec["roofline_fraction"] = (
            useful / (R.PEAK_FLOPS * chips * bound) if bound > 0 else 0.0
        )
    return rec


def main() -> None:
    import repro.configs as configs
    from repro.launch.shapes import SHAPES, cell_matrix

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true", help="hillclimb knob")
    ap.add_argument("--gather-weights", action="store_true", help="ZeRO-3 gather-on-use")
    ap.add_argument("--dp-only", action="store_true",
                    help="fold the model axis into DP/FSDP (no TP)")
    ap.add_argument("--params-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--decode-2d", action="store_true",
                    help="decode: 2D weight-stationary TP over (data,model), "
                         "seq-sharded KV, replicated per-token activations")
    ap.add_argument("--auto-policy", action="store_true",
                    help="use Policy.recommended (the hillclimbed presets)")
    ap.add_argument("--seq-shard", action="store_true", help="Megatron-SP residual")
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shape, status in cell_matrix():
            print(f"{arch:28s} {shape:12s} {status}")
        return

    overrides = {}
    if args.no_fsdp:
        overrides["fsdp"] = ()
    if args.dp_only:
        axes = ("pod", "data", "model") if args.multi_pod else ("data", "model")
        overrides.update(dp=axes, fsdp=axes, tp=None)
    if args.decode_2d:
        overrides.update(dp=(), fsdp=(), tp=("data", "model"), shard_seq=True)
    if args.auto_policy:
        overrides["auto"] = True
    try:
        rec = run_cell(
            configs.canonical(args.arch),
            args.shape,
            multi_pod=args.multi_pod,
            opt_dtype=args.opt_dtype,
            remat=args.remat,
            microbatches=args.microbatches,
            policy_overrides=overrides or None,
            gather_weights=args.gather_weights,
            seq_shard=args.seq_shard,
            params_dtype=args.params_dtype,
        )
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure for the report
        rec = {
            "arch": args.arch,
            "shape": args.shape,
            "multi_pod": args.multi_pod,
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(rec["traceback"])
    out = args.out or (
        f"experiments/dryrun/{configs.canonical(args.arch)}__{args.shape}"
        f"__{'pod2' if args.multi_pod else 'pod1'}.json"
    )
    Path(out).parent.mkdir(parents=True, exist_ok=True)
    Path(out).write_text(json.dumps(rec, indent=2, default=str))
    print(f"wrote {out}: status={rec['status']}")
    if rec["status"] != "ok":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
