"""Assigned input shapes and the (arch x shape) cell matrix.

``input_specs`` builds ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation — the dry-run contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models.config import ModelConfig
# repro.models (init_params/init_cache -> the full model + dist layers) is
# imported inside the *_struct functions: `dryrun --list` / `cell_matrix`
# must keep working when a heavyweight subsystem is broken.


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "long"),
}


def cell_matrix() -> list[tuple[str, str, str]]:
    """All 40 (arch, shape, status) cells; status 'run' or a skip reason."""
    out = []
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for sname in SHAPES:
            if sname == "long_500k" and not cfg.subquadratic:
                out.append((arch, sname, "skip: pure full-attention at 512k"))
            else:
                out.append((arch, sname, "run"))
    return out


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_struct(cfg: ModelConfig, sh: ShapeSpec) -> dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs."""
    B, S = sh.global_batch, sh.seq_len
    batch: dict[str, Any] = {}
    if cfg.frontend == "embed":
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def params_struct(cfg: ModelConfig):
    from repro.models import init_params

    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


def cache_struct(cfg: ModelConfig, B: int, max_seq: int):
    from repro.models import init_cache

    return jax.eval_shape(lambda: init_cache(cfg, B, max_seq))


def decode_inputs_struct(cfg: ModelConfig, sh: ShapeSpec) -> dict[str, Any]:
    """serve_step inputs: cache holds seq_len-1 tokens, one new token in."""
    B, S = sh.global_batch, sh.seq_len
    d: dict[str, Any] = {
        "cache": cache_struct(cfg, B, S),
        "pos": _sds((B,), jnp.int32),
        "xi": _sds((B,), jnp.float32),
    }
    if cfg.frontend == "embed":
        d["token"] = _sds((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        d["token"] = _sds((B,), jnp.int32)
    if cfg.encoder_layers:
        d["enc_out"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    return d
