"""Production serving launcher: continuous batching + radix-CDF QMC sampler.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mode", default="inverse_qmc",
                    choices=["inverse_qmc", "inverse_rng", "alias"])
    args = ap.parse_args()

    import numpy as np
    import jax

    import repro.configs as C
    from repro.models import init_params
    from repro.serve import Request, ServeEngine, TokenSampler

    cfg = C.get_reduced(args.arch)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(
        params, cfg, n_slots=args.slots, max_seq=256,
        sampler=TokenSampler(mode=args.mode, n_slots=args.slots, use_pallas=False),
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8), max_new=args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens, "
          f"{eng.steps} batched decode steps, sampler={args.mode}")


if __name__ == "__main__":
    main()
