"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms (assignment formulas; TPU v5e constants):
    t_compute = FLOPs_global    / (chips * 197e12)     [bf16 peak]
    t_mem     = HBM_bytes_global/ (chips * 819e9)
    t_coll    = coll_bytes_global/(chips * 50e9)       [per-link ICI]

``cost_analysis()`` semantics (global vs per-device FLOPs) are calibrated
empirically once per process with a known sharded matmul — see
``calibrate_cost_semantics``; results are normalized to GLOBAL before the
formulas. Collective bytes are parsed from the post-SPMD optimized HLO
(shapes there are per-device); we report both raw operand bytes and a
ring-algorithm wire estimate (all-reduce 2x(n-1)/n, all-gather (n-1)/n ...).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(s: str) -> int:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)           # iota form: [n_groups,group_size]<=..
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)      # explicit form: {{0,1,2,...},{...}}
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def parse_collectives(
    hlo_text: str, trip_hints: tuple[int, ...] = ()
) -> dict[str, dict[str, float]]:
    """Per-collective-kind byte totals from optimized (per-device) HLO.

    Operands are rendered without shapes in optimized dumps, so per-op
    operand bytes are derived from the result shape R and group size G:
      all-reduce: op=R            wire=2*R*(G-1)/G
      all-gather: op=R/G          wire=R*(G-1)/G
      reduce-scatter: op=R*G      wire=R*(G-1)
      all-to-all: op=R            wire=R*(G-1)/G
      collective-permute: op=R    wire=R

    HloCostAnalysis-style text counts a while (lax.scan) body ONCE; real
    execution runs it trip_count times. Each op's jax scope survives in
    metadata op_name, so ops at while-nesting depth d are multiplied by
    prod(trip_hints[:d]) (e.g. (n_periods,) for the layer scan, or
    (microbatches, n_periods) with gradient accumulation).
    """
    out: dict[str, dict[str, float]] = {
        k: {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0}
        for k in _COLL_KINDS
    }
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.search(
            r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+([\w-]+?)(-start)?\(", ls
        )
        if not m:
            continue
        result_s, op, started = m.group(1), m.group(2), m.group(3)
        if op not in _COLL_KINDS:
            continue
        kind = op
        shapes = _SHAPE_RE.findall(result_s)
        rbytes = sum(_shape_bytes(f"{dt}[{dims}]") for dt, dims in shapes)
        if started and len(shapes) >= 2:
            rbytes = rbytes // 2  # -start tuples duplicate the buffer
        G = _group_size(ls)
        if kind == "all-reduce":
            obytes, wire = rbytes, 2.0 * rbytes * (G - 1) / G
        elif kind == "all-gather":
            obytes, wire = rbytes / G, rbytes * (G - 1) / G
        elif kind == "reduce-scatter":
            obytes, wire = rbytes * G, float(rbytes) * (G - 1)
        elif kind == "all-to-all":
            obytes, wire = rbytes, rbytes * (G - 1) / G
        else:  # collective-permute
            obytes, wire = rbytes, float(rbytes)
        mo = re.search(r'op_name="([^"]*)"', ls)
        depth = mo.group(1).count("/while/") if mo else 0
        mult = 1.0
        for d in range(depth):
            mult *= trip_hints[d] if d < len(trip_hints) else 1
        rec = out[kind]
        rec["count"] += 1
        rec["operand_bytes"] += obytes * mult
        rec["result_bytes"] += rbytes * mult
        rec["wire_bytes"] += wire * mult
    return out


_COST_SEMANTICS: dict[str, float] | None = None


def calibrate_cost_semantics(mesh) -> dict[str, float]:
    """Determine whether compiled.cost_analysis() reports global or
    per-device FLOPs by compiling a known matmul sharded over the mesh."""
    global _COST_SEMANTICS
    if _COST_SEMANTICS is not None:
        return _COST_SEMANTICS
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    ndev = int(np.prod(list(mesh.shape.values())))
    M = N = K = 1024
    expect_global = 2 * M * N * K
    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    y = jax.ShapeDtypeStruct((K, N), jnp.float32)
    axis0 = tuple(mesh.axis_names)[0]
    sx = NamedSharding(mesh, P(axis0, None))
    sy = NamedSharding(mesh, P(None, None))
    comp = (
        jax.jit(lambda a, b: a @ b, in_shardings=(sx, sy))
        .lower(x, y)
        .compile()
    )
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    flops = float(ca.get("flops", 0.0))
    ratio = flops / expect_global if expect_global else 0.0
    # ratio ~1 -> global; ~1/ndev -> per-device
    scale = 1.0 if ratio > 0.5 else float(ndev) if ratio > 0 else 0.0
    _COST_SEMANTICS = {"flops_scale_to_global": scale, "calib_ratio": ratio}
    return _COST_SEMANTICS


@dataclasses.dataclass
class Roofline:
    chips: int
    flops_global: float
    bytes_global: float
    coll_bytes_global: float     # raw operand-byte convention (assignment)
    coll_wire_global: float      # ring-algorithm estimate
    collectives: dict[str, dict[str, float]]
    hlo_flops_global: float = 0.0   # raw cost_analysis (while bodies once)
    hlo_bytes_global: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_mem(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_coll(self) -> float:
        return self.coll_bytes_global / (self.chips * LINK_BW)

    @property
    def t_coll_wire(self) -> float:
        return self.coll_wire_global / (self.chips * LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_mem,
            "collective": max(self.t_coll, self.t_coll_wire),
        }
        return max(terms, key=terms.get)

    def to_dict(self) -> dict[str, Any]:
        return {
            "chips": self.chips,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "coll_bytes_global": self.coll_bytes_global,
            "coll_wire_global": self.coll_wire_global,
            "t_compute_s": self.t_compute,
            "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll,
            "t_coll_wire_s": self.t_coll_wire,
            "dominant": self.dominant,
            "hlo_flops_global": self.hlo_flops_global,
            "hlo_bytes_global": self.hlo_bytes_global,
            "collectives": self.collectives,
        }


def analyze(
    compiled,
    mesh,
    chips: int,
    trip_hints: tuple[int, ...] = (),
    analytic_flops: float | None = None,
    analytic_bytes: float | None = None,
) -> Roofline:
    sem = calibrate_cost_semantics(mesh)
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else (ca or {})
    hlo_flops = float(ca.get("flops", 0.0)) * sem["flops_scale_to_global"]
    hlo_bytes = float(ca.get("bytes accessed", 0.0)) * sem["flops_scale_to_global"]
    # HloCostAnalysis counts while bodies once -> prefer the analytic model
    # for scanned modules (hlo_* kept as cross-check fields).
    flops = analytic_flops if analytic_flops else hlo_flops
    hbm = analytic_bytes if analytic_bytes else hlo_bytes
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    colls = parse_collectives(hlo, trip_hints)
    # HLO shapes are per-device -> multiply by chips for global bytes
    coll_raw = sum(c["operand_bytes"] for c in colls.values()) * chips
    coll_wire = sum(c["wire_bytes"] for c in colls.values()) * chips
    r = Roofline(
        chips=chips,
        flops_global=flops,
        bytes_global=hbm,
        coll_bytes_global=coll_raw,
        coll_wire_global=coll_wire,
        collectives=colls,
    )
    r.hlo_flops_global = hlo_flops
    r.hlo_bytes_global = hlo_bytes
    return r


def model_flops(cfg, tokens: int) -> dict[str, float]:
    total, active = cfg.param_count()
    return {
        "model_flops_6ND": 6.0 * total * tokens,
        "model_flops_6NactiveD": 6.0 * active * tokens,
    }
