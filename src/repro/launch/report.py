"""Build the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON records.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def fmt_s(s: float) -> str:
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s * 1e6:.0f}us"
    if s < 1:
        return f"{s * 1e3:.1f}ms"
    return f"{s:.2f}s"


def load(dirpath: str) -> list[dict]:
    recs = []
    for f in sorted(Path(dirpath).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def rec_mesh(r: dict) -> str:
    m = r.get("mesh")
    if isinstance(m, str):
        return m
    if isinstance(m, dict):
        return "pod2" if len(m) == 3 else "pod1"
    return "pod1"


def dryrun_table(recs: list[dict], mesh: str) -> list[str]:
    out = [
        "| arch | shape | status | lower | compile | args/dev | temp/dev | HLO flops (global) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if rec_mesh(r) == mesh:
            if r["status"] == "skipped":
                out.append(
                    f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'].split(':')[-1].strip()}) | | | | | |"
                )
                continue
            if r["status"] != "ok":
                out.append(f"| {r['arch']} | {r['shape']} | **{r['status']}** | | | | | |")
                continue
            chips = r["chips"]
            args_dev = r.get("argument_size_in_bytes", 0)
            temp_dev = r.get("temp_size_in_bytes", 0)
            out.append(
                f"| {r['arch']} | {r['shape']} | ok | {r['lower_s']:.1f}s "
                f"| {r['compile_s']:.1f}s | {fmt_bytes(args_dev)} "
                f"| {fmt_bytes(temp_dev)} "
                f"| {r['roofline'].get('hlo_flops_global', r['roofline']['flops_global']):.2e} |"
            )
    return out


def roofline_table(recs: list[dict]) -> list[str]:
    out = [
        "| arch | shape | t_compute | t_mem | t_coll (raw) | t_coll (wire) | dominant | 6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") != "ok" or rec_mesh(r) != "pod1":
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['t_compute_s'])} "
            f"| {fmt_s(rf['t_mem_s'])} | {fmt_s(rf['t_coll_s'])} "
            f"| {fmt_s(rf['t_coll_wire_s'])} | {rf['dominant']} "
            f"| {r.get('useful_flops', 0) / max(rf['flops_global'], 1):.2f} "
            f"| {r.get('roofline_fraction', 0) * 100:.1f}% |"
        )
    return out


def optimized_table(base: list[dict], opt: list[dict]) -> list[str]:
    bidx = {(r.get("arch"), r.get("shape")): r for r in base
            if r.get("status") == "ok" and rec_mesh(r) == "pod1"}
    out = [
        "| arch | shape | t_coll base -> opt | x | dominant after | frac base -> opt |",
        "|---|---|---|---|---|---|",
    ]
    for r in opt:
        if r.get("status") != "ok":
            continue
        b = bidx.get((r["arch"], r["shape"]))
        if not b:
            continue
        tb = b["roofline"]["t_coll_s"]
        to = r["roofline"]["t_coll_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(tb)} -> {fmt_s(to)} "
            f"| {tb / max(to, 1e-12):.1f}x | {r['roofline']['dominant']} "
            f"| {b.get('roofline_fraction', 0) * 100:.1f}% -> "
            f"{r.get('roofline_fraction', 0) * 100:.1f}% |"
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--opt-dir", default="experiments/dryrun_opt")
    args = ap.parse_args()
    recs = load(args.dir)
    print("### Dry-run, single pod (16x16 = 256 chips)\n")
    print("\n".join(dryrun_table(recs, "pod1")))
    print("\n### Dry-run, multi-pod (2x16x16 = 512 chips)\n")
    print("\n".join(dryrun_table(recs, "pod2")))
    print("\n### Roofline (single pod)\n")
    print("\n".join(roofline_table(recs)))
    if Path(args.opt_dir).exists():
        print("\n### Optimized policy (auto-policy + gather hints) vs baseline\n")
        print("\n".join(optimized_table(recs, load(args.opt_dir))))


if __name__ == "__main__":
    main()
