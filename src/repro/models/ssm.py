"""Mamba-style selective SSM block (Jamba's sequence mixer).

Training path uses an **associative scan** over time (O(log S) depth — the
same parallel-prefix machinery as the paper's CDF build), so 4k-32k training
sequences lower without a sequential loop. Decode carries (conv window,
ssm state) per layer: O(1) per token — this is what makes jamba/xlstm the
long_500k architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import Params, _init


def init_mamba(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    DI = cfg.ssm_expand * D
    N = cfg.ssm_state
    ks = jax.random.split(key, 7)
    dt_bias = jnp.log(jnp.expm1(jnp.exp(
        jax.random.uniform(ks[5], (DI,), minval=np.log(1e-3), maxval=np.log(1e-1))
    )))
    return {
        "in_proj": _init(ks[0], (D, 2 * DI)),
        "conv": _init(ks[1], (cfg.ssm_conv, DI), scale=0.5),
        "x_proj": _init(ks[2], (DI, 2 * N + 1)),     # -> (B, C, dt)
        "dt_bias": dt_bias,
        "a_log": jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :]
        * jnp.ones((DI, 1), jnp.float32),
        "d_skip": jnp.ones((DI,), jnp.float32),
        "out_proj": _init(ks[4], (DI, D)),
    }


def _ssm_scan(a: jax.Array, bx: jax.Array):
    """h_t = a_t * h_{t-1} + bx_t along axis 1 via associative scan."""

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def mamba(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence forward. x (B, S, D)."""
    B, S, D = x.shape
    DI = cfg.ssm_expand * D
    N = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)

    # causal depthwise conv
    K = cfg.ssm_conv
    pad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(
        pad[:, k : k + S, :] * p["conv"][k].astype(x.dtype) for k in range(K)
    )
    u = jax.nn.silu(conv)

    proj = jnp.einsum("bsi,ie->bse", u, p["x_proj"].astype(x.dtype)).astype(jnp.float32)
    Bm, Cm, dt = proj[..., :N], proj[..., N : 2 * N], proj[..., -1:]
    dt = jax.nn.softplus(dt + p["dt_bias"])                # (B,S,DI)
    A = -jnp.exp(p["a_log"])                                # (DI,N)
    uf = u.astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A[None, None])              # (B,S,DI,N)
    bx = (dt[..., None] * Bm[:, :, None, :]) * uf[..., None]
    h = _ssm_scan(a, bx)                                    # (B,S,DI,N)
    y = jnp.einsum("bsin,bsn->bsi", h, Cm) + uf * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))


def mamba_init_cache(cfg: ModelConfig, B: int, dtype) -> dict:
    DI = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((B, cfg.ssm_conv - 1, DI), dtype),
        "h": jnp.zeros((B, DI, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: dict):
    """One-token step. x (B,1,D); O(1) state update."""
    B = x.shape[0]
    N = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([cache["conv"], xi], axis=1)    # (B,K,DI)
    conv = jnp.einsum("bki,ki->bi", window, p["conv"].astype(x.dtype))[:, None]
    u = jax.nn.silu(conv)
    proj = jnp.einsum("bsi,ie->bse", u, p["x_proj"].astype(x.dtype)).astype(jnp.float32)
    Bm, Cm, dt = proj[..., :N], proj[..., N : 2 * N], proj[..., -1:]
    dt = jax.nn.softplus(dt + p["dt_bias"])                 # (B,1,DI)
    A = -jnp.exp(p["a_log"])
    uf = u.astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A[None, None])[:, 0]         # (B,DI,N)
    bx = ((dt[..., None] * Bm[:, :, None, :]) * uf[..., None])[:, 0]
    h = a * cache["h"] + bx
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0])[:, None] + uf * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": window[:, 1:], "h": h}
