"""Unified model configuration covering all 10 assigned architectures.

One decoder-centric description: a repeating *super-block* of per-layer
block types (attention / mamba / mlstm / slstm) and MLP types (dense / moe /
none), plus an optional encoder stack (Whisper) and modality frontends
(stubs supplying precomputed embeddings, per the assignment).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "mamba", "mlstm", "slstm"]
MlpKind = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # Super-block structure; len(block_pattern) must divide n_layers.
    block_pattern: tuple[str, ...] = ("attn",)
    mlp_pattern: tuple[str, ...] = ("dense",)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_ff: int = 0                   # expert hidden size (defaults to d_ff)
    capacity_factor: float = 1.25
    router_noise: bool = False        # stochastic routing via radix-forest QMC

    # Attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    causal: bool = True
    attn_impl: str = "einsum"   # einsum | flash (Pallas online-softmax)

    # SSM (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2

    # xLSTM
    mlstm_chunk: int = 128

    # Encoder-decoder (Whisper)
    encoder_layers: int = 0
    cross_attention: bool = False

    # Frontend: none -> tokens; embed -> precomputed embeddings (VLM stub);
    # audio -> precomputed frame embeddings into the encoder (conv stub).
    frontend: str = "none"

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # long_500k eligibility: SSM/hybrid/linear-attn (i.e. not *pure* full
    # attention). Hybrid decode is O(S) per token; pure-attention 512k decode
    # is skipped per the assignment.
    @property
    def subquadratic(self) -> bool:
        return any(b != "attn" for b in self.block_pattern)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            self.name, self.n_layers, self.block_pattern)
        return self.n_layers // len(self.block_pattern)

    @property
    def expert_ff(self) -> int:
        return self.moe_ff or self.d_ff

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter estimate (embeddings included)."""
        D, V = self.d_model, self.vocab
        hd = self.hd
        total = V * D * (1 if self.tie_embeddings else 2)
        active = total
        period = len(self.block_pattern)
        for li in range(self.n_layers):
            b = self.block_pattern[li % period]
            m = self.mlp_pattern[li % len(self.mlp_pattern)]
            if b == "attn":
                a = D * self.n_heads * hd * 2 + D * self.n_kv_heads * hd * 2
                if self.cross_attention:
                    a *= 2
            elif b == "mamba":
                di = self.ssm_expand * D
                a = D * di * 2 + di * D + di * (self.ssm_state * 2 + 2) + di * self.ssm_conv
            else:  # mlstm / slstm
                di = 2 * D if b == "mlstm" else D
                a = D * di * 4 + di * D + di * 3
            total += a
            active += a
            if m == "dense":
                f = 3 * D * self.d_ff
                total += f
                active += f
            elif m == "moe":
                f = 3 * D * self.expert_ff
                total += f * (self.n_experts + self.n_shared_experts) + D * self.n_experts
                active += f * (self.top_k + self.n_shared_experts) + D * self.n_experts
        # encoder stack (attention + dense mlp)
        for _ in range(self.encoder_layers):
            a = D * self.n_heads * hd * 2 + D * self.n_kv_heads * hd * 2
            f = 3 * D * self.d_ff
            total += a + f
            active += a + f
        return total, active


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Small same-family variant for CPU smoke tests."""
    period = len(cfg.block_pattern)
    small = dict(
        n_layers=period * min(2, cfg.n_periods),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        moe_ff=128 if cfg.moe_ff else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        ssm_state=8,
        mlstm_chunk=16,
        name=cfg.name + "-reduced",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
