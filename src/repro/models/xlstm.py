"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM (xlstm-1.3b).

mLSTM keeps a matrix memory C (H, hd, hd) with input/forget gating:
    C_t = f_t C_{t-1} + i_t v_t k_t^T,  n_t = f_t n_{t-1} + i_t k_t
    h_t = o_t * (q_t C_t) / max(|q_t . n_t|, 1)
Training uses a chunkwise-parallel form (intra-chunk quadratic in chunk size,
inter-chunk recurrent in log-forget space) — sub-quadratic in S, which is why
this arch runs the long_500k shape. Forget gates are sigmoid (log f <= 0, so
intra-chunk decay ratios never overflow); input gates exp-capped.

sLSTM is the scalar-memory variant with exponential gating and the max-
stabilizer m_t; it is inherently sequential -> lax.scan over time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import Params, _init


# ------------------------------------------------------------------- mLSTM


def init_mlstm(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    DI = 2 * D
    H = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": _init(ks[0], (D, 2 * DI)),
        "wq": _init(ks[1], (DI, DI)),
        "wk": _init(ks[2], (DI, DI)),
        "wv": _init(ks[3], (DI, DI)),
        "wif": _init(ks[4], (DI, 2 * H), scale=0.02),
        "if_bias": jnp.concatenate(
            [jnp.full((H,), -3.0), jnp.full((H,), 3.0)]  # i low, f high
        ),
        "down": _init(ks[6], (DI, D)),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk: int):
    """q/k/v (B, S, H, hd); log_f/log_i (B, S, H). Returns h (B, S, H, hd)."""
    B, S, H, hd = q.shape
    C = chunk
    assert S % C == 0, (S, C)
    nc = S // C
    qc = q.reshape(B, nc, C, H, hd)
    kc = k.reshape(B, nc, C, H, hd)
    vc = v.reshape(B, nc, C, H, hd)
    lf = log_f.reshape(B, nc, C, H).astype(jnp.float32)
    li = log_i.reshape(B, nc, C, H).astype(jnp.float32)

    F = jnp.cumsum(lf, axis=2)                  # within-chunk cumulative log f
    Ftot = F[:, :, -1]                          # (B,nc,H)
    # intra-chunk decay: D[j,t] = exp(F_j - F_t + li_t) for t <= j
    decay = F[:, :, :, None, :] - F[:, :, None, :, :] + li[:, :, None, :, :]
    mask = (jnp.arange(C)[:, None] >= jnp.arange(C)[None, :])[None, None, :, :, None]
    intra = jnp.where(mask, jnp.exp(jnp.minimum(decay, 20.0)), 0.0)  # (B,nc,j,t,H)

    qk = jnp.einsum("bnjhd,bnthd->bnjth", qc, kc).astype(jnp.float32)
    w = qk * intra                              # (B,nc,j,t,H)
    h_intra = jnp.einsum("bnjth,bnthd->bnjhd", w.astype(q.dtype), vc)
    n_intra = jnp.einsum("bnjth,bnthd->bnjhd", w.astype(q.dtype), kc)

    # Inter-chunk recurrent state over chunks (sequential scan over nc):
    # Cc = exp(Ftot) C_prev + sum_t exp(Ftot - F_t + li_t) v_t k_t^T
    gain = jnp.exp(jnp.minimum(Ftot[:, :, None, :] - F + li, 20.0))  # (B,nc,C,H)
    dC = jnp.einsum("bnth,bnthd,bnthe->bnhde", gain.astype(q.dtype), vc, kc)
    dn = jnp.einsum("bnth,bnthd->bnhd", gain.astype(q.dtype), kc)

    def step(carry, xs):
        Cst, nst = carry
        dC_n, dn_n, ftot = xs
        decay_c = jnp.exp(jnp.minimum(ftot, 0.0))[:, :, None, None]
        Cn = Cst * decay_c.astype(Cst.dtype) + dC_n
        nn = nst * decay_c[..., 0].astype(nst.dtype) + dn_n
        return (Cn, nn), (Cst, nst)

    C0 = jnp.zeros((B, H, hd, hd), q.dtype)
    n0 = jnp.zeros((B, H, hd), q.dtype)
    xs = (
        dC.transpose(1, 0, 2, 3, 4),
        dn.transpose(1, 0, 2, 3),
        Ftot.transpose(1, 0, 2),
    )
    (_, _), (Cprev, nprev) = jax.lax.scan(step, (C0, n0), xs)
    Cprev = Cprev.transpose(1, 0, 2, 3, 4)      # (B,nc,H,hd,hd) state entering chunk
    nprev = nprev.transpose(1, 0, 2, 3)         # (B,nc,H,hd)

    carry_w = jnp.exp(jnp.minimum(F, 0.0))      # exp(F_j) <= 1 (sigmoid forget)
    h_inter = jnp.einsum("bnjh,bnjhd,bnhde->bnjhe",
                         carry_w.astype(q.dtype), qc, Cprev)
    n_inter = jnp.einsum("bnjh,bnjhd,bnhd->bnjh",
                         carry_w.astype(q.dtype), qc, nprev)
    qn = jnp.einsum("bnjhd,bnjhd->bnjh", qc, n_intra) + n_inter
    denom = jnp.maximum(jnp.abs(qn.astype(jnp.float32)), 1.0)[..., None]
    h = (h_intra + h_inter).astype(jnp.float32) / denom
    return h.reshape(B, S, H, hd).astype(q.dtype)


def mlstm(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, D = x.shape
    H = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, p["up"].astype(x.dtype))
    xin, z = jnp.split(up, 2, axis=-1)
    DI = xin.shape[-1]
    hd = DI // H
    q = jnp.einsum("bse,ef->bsf", xin, p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", xin, p["wk"].astype(x.dtype)).reshape(B, S, H, hd)
    # fold 1/sqrt(hd) into k (consistent intra/inter/decode); python-float
    # scalar stays weakly typed so bf16 activations are not promoted
    k = k * (1.0 / float(np.sqrt(hd)))
    v = jnp.einsum("bse,ef->bsf", xin, p["wv"].astype(x.dtype)).reshape(B, S, H, hd)
    gates = jnp.einsum("bse,eg->bsg", xin, p["wif"].astype(x.dtype)).astype(jnp.float32)
    gates = gates + p["if_bias"]
    log_i = jnp.minimum(gates[..., :H], 10.0)           # exp input gate, capped
    log_f = jax.nn.log_sigmoid(gates[..., H:])          # sigmoid forget gate
    chunk = min(cfg.mlstm_chunk, S)
    while S % chunk:
        chunk -= 1
    h = _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk)
    h = h.reshape(B, S, DI) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", h, p["down"].astype(x.dtype))


def mlstm_init_cache(cfg: ModelConfig, B: int, dtype) -> dict:
    H = cfg.n_heads
    hd = 2 * cfg.d_model // H
    return {
        "C": jnp.zeros((B, H, hd, hd), jnp.float32),
        "n": jnp.zeros((B, H, hd), jnp.float32),
    }


def mlstm_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: dict):
    B = x.shape[0]
    H = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, p["up"].astype(x.dtype))
    xin, z = jnp.split(up, 2, axis=-1)
    DI = xin.shape[-1]
    hd = DI // H
    proj = lambda w: jnp.einsum("bse,ef->bsf", xin, w.astype(x.dtype)).reshape(B, H, hd)
    q, k, v = proj(p["wq"]), proj(p["wk"]), proj(p["wv"])
    gates = jnp.einsum("bse,eg->bsg", xin, p["wif"].astype(x.dtype)).astype(jnp.float32)
    gates = (gates + p["if_bias"])[:, 0]
    i = jnp.exp(jnp.minimum(gates[..., :H], 10.0))
    f = jax.nn.sigmoid(gates[..., H:])
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) / np.sqrt(hd)
    vf = v.astype(jnp.float32)
    C = cache["C"] * f[..., None, None] + i[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", vf, kf
    )
    n = cache["n"] * f[..., None] + i[..., None] * kf
    num = jnp.einsum("bhde,bhe->bhd", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf))[..., None], 1.0)
    h = (num / den).reshape(B, 1, DI).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, p["down"].astype(x.dtype))
    return out, {"C": C, "n": n}


# ------------------------------------------------------------------- sLSTM


def init_slstm(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    H = cfg.n_heads
    hd = D // H
    ks = jax.random.split(key, 4)
    return {
        "wx": _init(ks[0], (D, 4 * D)),
        "r": _init(ks[1], (H, hd, 4 * hd), scale=0.3 / np.sqrt(hd)),
        "bias": jnp.zeros((4 * D,), jnp.float32)
        .at[2 * D : 3 * D].set(1.0),   # forget bias
        "down": _init(ks[2], (D, D)),
    }


def _slstm_cell(p, cfg, wx_t, state):
    """wx_t (B, 4D) precomputed input proj; state (h, c, n, m) each (B,H,hd)."""
    h, c, n, m = state
    B = wx_t.shape[0]
    H = cfg.n_heads
    D = cfg.d_model
    hd = D // H
    rec = jnp.einsum("bhd,hde->bhe", h, p["r"].astype(h.dtype))   # (B,H,4hd)
    z = wx_t.reshape(B, H, 4 * hd) + rec
    z = z.astype(jnp.float32) + p["bias"].reshape(H, 4 * hd)
    zi, zz, zf, zo = jnp.split(z, 4, axis=-1)
    m_new = jnp.maximum(zf + m, zi)
    i = jnp.exp(zi - m_new)
    f = jnp.exp(zf + m - m_new)
    c_new = f * c + i * jnp.tanh(zz)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
    return (h_new.astype(h.dtype), c_new, n_new, m_new)


def slstm(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    wx = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))

    def step(state, wx_t):
        new = _slstm_cell(p, cfg, wx_t, state)
        return new, new[0]

    init = (
        jnp.zeros((B, H, hd), x.dtype),
        jnp.zeros((B, H, hd), jnp.float32),
        jnp.zeros((B, H, hd), jnp.float32),
        jnp.full((B, H, hd), -1e30, jnp.float32),
    )
    _, hs = jax.lax.scan(step, init, wx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, D)
    return jnp.einsum("bsd,de->bse", y, p["down"].astype(x.dtype))


def slstm_init_cache(cfg: ModelConfig, B: int, dtype) -> dict:
    H = cfg.n_heads
    hd = cfg.d_model // H
    return {
        "h": jnp.zeros((B, H, hd), dtype),
        "c": jnp.zeros((B, H, hd), jnp.float32),
        "n": jnp.zeros((B, H, hd), jnp.float32),
        "m": jnp.full((B, H, hd), -1e30, jnp.float32),
    }


def slstm_decode(p: Params, cfg: ModelConfig, x: jax.Array, cache: dict):
    wx = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))[:, 0]
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_cell(p, cfg, wx, state)
    B = x.shape[0]
    y = h.reshape(B, 1, cfg.d_model)
    out = jnp.einsum("bsd,de->bse", y, p["down"].astype(x.dtype))
    return out, {"h": h, "c": c, "n": n, "m": m}
