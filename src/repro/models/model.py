"""Unified LM: assembles attention / mamba / mLSTM / sLSTM blocks with dense
or MoE MLPs into a scanned super-block stack, plus optional encoder stack
(Whisper) and embedding frontends (VLM/audio stubs).

Layers are stacked over the super-block period and iterated with
``jax.lax.scan`` so HLO size (and 512-device SPMD partitioning time) is
independent of depth; remat wraps the scan body.

Three entry points used by the runtime:
  * ``forward``      — full-sequence logits (training / eval)
  * ``prefill``      — full-sequence + returns decode caches
  * ``decode_step``  — one token through the cached stack
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist import hints as H

from . import layers as L
from . import moe as M
from . import ssm as S
from . import xlstm as X
from .config import ModelConfig

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ------------------------------------------------------------------ params


def _init_block(key, cfg: ModelConfig, kind: str) -> Params:
    if kind == "attn":
        return L.init_attention(key, cfg)
    if kind == "mamba":
        return S.init_mamba(key, cfg)
    if kind == "mlstm":
        return X.init_mlstm(key, cfg)
    if kind == "slstm":
        return X.init_slstm(key, cfg)
    raise ValueError(kind)


def _init_mlp(key, cfg: ModelConfig, kind: str) -> Params:
    if kind == "dense":
        return L.init_mlp(key, cfg.d_model, cfg.d_ff)
    if kind == "moe":
        return M.init_moe(key, cfg)
    return {}


def _init_period(key, cfg: ModelConfig) -> Params:
    p: Params = {}
    n = len(cfg.block_pattern)
    ks = jax.random.split(key, 4 * n)
    for i, kind in enumerate(cfg.block_pattern):
        p[f"b{i}"] = _init_block(ks[4 * i], cfg, kind)
        p[f"ln_b{i}"] = L.init_rmsnorm(cfg.d_model)
        mk = cfg.mlp_pattern[i % len(cfg.mlp_pattern)]
        if mk != "none":
            p[f"m{i}"] = _init_mlp(ks[4 * i + 1], cfg, mk)
            p[f"ln_m{i}"] = L.init_rmsnorm(cfg.d_model)
        if cfg.cross_attention and kind == "attn":
            p[f"x{i}"] = L.init_cross_attention(ks[4 * i + 2], cfg)
            p[f"ln_x{i}"] = L.init_rmsnorm(cfg.d_model)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {}
    if cfg.frontend != "embed":
        p["embed"] = L._init(ks[0], (cfg.vocab, cfg.d_model), scale=0.02)
    # stacked decoder periods
    period_keys = jax.random.split(ks[1], cfg.n_periods)
    p["layers"] = jax.vmap(lambda k: _init_period(k, cfg))(period_keys)
    if cfg.encoder_layers:
        enc_keys = jax.random.split(ks[2], cfg.encoder_layers)
        enc_cfg = cfg  # same dims
        p["encoder"] = jax.vmap(
            lambda k: {
                "attn": L.init_attention(k, enc_cfg),
                "ln_a": L.init_rmsnorm(cfg.d_model),
                "mlp": L.init_mlp(k, cfg.d_model, cfg.d_ff),
                "ln_m": L.init_rmsnorm(cfg.d_model),
            }
        )(enc_keys)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model)
    p["final_norm"] = L.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"] = L._init(ks[3], (cfg.d_model, cfg.vocab), scale=0.02)
    return p


# ----------------------------------------------------------------- encoder


def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Bidirectional encoder over stub frame embeddings (B, S_enc, D)."""
    x = frames.astype(_dtype(cfg))
    B, Senc, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(Senc)[None], (B, Senc))

    def body(x, lp):
        lp = H.gather_params(lp)
        h = L.rmsnorm(lp["ln_a"], x, cfg.norm_eps)
        x = x + L.attention(lp["attn"], cfg, h, pos, causal=False)
        h = L.rmsnorm(lp["ln_m"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ----------------------------------------------------------------- forward


def _period_forward(cfg: ModelConfig, pp: Params, x, pos, enc_out):
    aux = jnp.zeros((), jnp.float32)
    pp = H.gather_params(pp)   # ZeRO-3 gather-on-use (no-op without hints)
    x = H.act_seq(x)           # Megatron-SP residual (no-op without hints)
    for i, kind in enumerate(cfg.block_pattern):
        h = L.rmsnorm(pp[f"ln_b{i}"], x, cfg.norm_eps)
        if kind == "attn":
            y = L.attention(pp[f"b{i}"], cfg, h, pos, causal=cfg.causal)
        elif kind == "mamba":
            y = S.mamba(pp[f"b{i}"], cfg, h)
        elif kind == "mlstm":
            y = X.mlstm(pp[f"b{i}"], cfg, h)
        else:
            y = X.slstm(pp[f"b{i}"], cfg, h)
        x = x + y
        if cfg.cross_attention and kind == "attn":
            h = L.rmsnorm(pp[f"ln_x{i}"], x, cfg.norm_eps)
            kv = L.encoder_kv(pp[f"x{i}"], cfg, enc_out)
            x = x + L.cross_attention(pp[f"x{i}"], cfg, h, kv)
        mk = cfg.mlp_pattern[i % len(cfg.mlp_pattern)]
        if mk == "dense":
            h = L.rmsnorm(pp[f"ln_m{i}"], x, cfg.norm_eps)
            x = x + L.mlp(pp[f"m{i}"], h)
        elif mk == "moe":
            h = L.rmsnorm(pp[f"ln_m{i}"], x, cfg.norm_eps)
            y, a = M.moe(pp[f"m{i}"], cfg, h)
            x = x + y
            aux = aux + a
    return x, aux


def _embed_in(params, cfg: ModelConfig, batch) -> jax.Array:
    if cfg.frontend == "embed":
        return batch["embeds"].astype(_dtype(cfg))
    tok = batch["tokens"]
    return params["embed"].astype(_dtype(cfg))[tok]


def _head(params, cfg: ModelConfig, x) -> jax.Array:
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        # keep the *embed* rule (V->tp, D gathered), then transpose
        w = H.gather_params({"embed": params["embed"]})["embed"].T
    else:
        w = H.gather_params({"lm_head": params["lm_head"]})["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


@functools.partial(jax.jit, static_argnames=("cfg", "remat"))
def forward(params: Params, cfg: ModelConfig, batch: dict, remat: str = "none"):
    """Full-sequence logits (B, S, V) + aux losses."""
    x = _embed_in(params, cfg, batch)
    B, Sq, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    enc_out = (
        encode(params, cfg, batch["frames"]) if cfg.encoder_layers else None
    )

    def body(carry, pp):
        x, aux = carry
        x, a = _period_forward(cfg, pp, x, pos, enc_out)
        return (x, aux + a), None

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    return _head(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch, remat: str = "none"):
    logits, aux = forward(params, cfg, batch, remat)
    labels = batch["labels"]
    lg = logits[:, :-1].astype(jnp.float32)
    tg = labels[:, 1:]
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    mask = (tg >= 0).astype(jnp.float32)
    nll = jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


# ------------------------------------------------------------------ decode


def init_cache(cfg: ModelConfig, B: int, max_seq: int) -> dict:
    dt = _dtype(cfg)

    def one_period(_):
        c = {}
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "attn":
                c[f"b{i}"] = {
                    "k": jnp.zeros((B, max_seq, cfg.n_kv_heads, cfg.hd), dt),
                    "v": jnp.zeros((B, max_seq, cfg.n_kv_heads, cfg.hd), dt),
                    "len": jnp.zeros((), jnp.int32),
                }
            elif kind == "mamba":
                c[f"b{i}"] = S.mamba_init_cache(cfg, B, dt)
            elif kind == "mlstm":
                c[f"b{i}"] = X.mlstm_init_cache(cfg, B, dt)
            else:
                c[f"b{i}"] = X.slstm_init_cache(cfg, B, dt)
        return c

    caches = [one_period(i) for i in range(cfg.n_periods)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


@functools.partial(jax.jit, static_argnames=("cfg",))
def decode_step(params: Params, cfg: ModelConfig, cache, token, pos, enc_out=None):
    """token (B,) int32 (or embeds (B,1,D)), pos (B,) int32 -> (logits (B,V), cache)."""
    if cfg.frontend == "embed" and token.ndim == 3:
        x = token.astype(_dtype(cfg))
    else:
        x = params["embed"].astype(_dtype(cfg))[token][:, None]

    def body(x, xs):
        pp, pc = xs
        pp = H.gather_params(pp)
        nc = {}
        for i, kind in enumerate(cfg.block_pattern):
            h = L.rmsnorm(pp[f"ln_b{i}"], x, cfg.norm_eps)
            if kind == "attn":
                y, nc[f"b{i}"] = L.attention_decode(pp[f"b{i}"], cfg, h, pc[f"b{i}"], pos)
            elif kind == "mamba":
                y, nc[f"b{i}"] = S.mamba_decode(pp[f"b{i}"], cfg, h, pc[f"b{i}"])
            elif kind == "mlstm":
                y, nc[f"b{i}"] = X.mlstm_decode(pp[f"b{i}"], cfg, h, pc[f"b{i}"])
            else:
                y, nc[f"b{i}"] = X.slstm_decode(pp[f"b{i}"], cfg, h, pc[f"b{i}"])
            x = x + y
            if cfg.cross_attention and kind == "attn":
                h = L.rmsnorm(pp[f"ln_x{i}"], x, cfg.norm_eps)
                kv = L.encoder_kv(pp[f"x{i}"], cfg, enc_out)
                x = x + L.cross_attention(pp[f"x{i}"], cfg, h, kv)
            mk = cfg.mlp_pattern[i % len(cfg.mlp_pattern)]
            if mk == "dense":
                h = L.rmsnorm(pp[f"ln_m{i}"], x, cfg.norm_eps)
                x = x + L.mlp(pp[f"m{i}"], h)
            elif mk == "moe":
                h = L.rmsnorm(pp[f"ln_m{i}"], x, cfg.norm_eps)
                y, _ = M.moe(pp[f"m{i}"], cfg, h)
                x = x + y
        return x, nc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    logits = _head(params, cfg, x)[:, 0]
    return logits, new_cache


@functools.partial(jax.jit, static_argnames=("cfg", "max_seq"))
def prefill(params: Params, cfg: ModelConfig, batch: dict, max_seq: int):
    """Run the prompt, return (last-position logits, decode cache, enc_out)."""
    x = _embed_in(params, cfg, batch)
    B, Sq, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    enc_out = (
        encode(params, cfg, batch["frames"]) if cfg.encoder_layers else None
    )
    cache = init_cache(cfg, B, max_seq)

    def body(carry, xs):
        x = carry
        pp, pc = xs
        pp = H.gather_params(pp)
        nc = dict(pc)
        for i, kind in enumerate(cfg.block_pattern):
            h = L.rmsnorm(pp[f"ln_b{i}"], x, cfg.norm_eps)
            if kind == "attn":
                q, k, v = L._qkv(pp[f"b{i}"], cfg, h, pos, rope=True)
                y = L._sdpa(q, k, v, cfg, causal=cfg.causal)
                y = jnp.einsum("bshk,hkd->bsd", y, pp[f"b{i}"]["wo"].astype(x.dtype))
                ck = jax.lax.dynamic_update_slice_in_dim(
                    pc[f"b{i}"]["k"], k.astype(pc[f"b{i}"]["k"].dtype), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    pc[f"b{i}"]["v"], v.astype(pc[f"b{i}"]["v"].dtype), 0, axis=1)
                nc[f"b{i}"] = {"k": ck, "v": cv, "len": jnp.int32(Sq)}
            elif kind == "mamba":
                y, nc[f"b{i}"] = _mamba_prefill(pp[f"b{i}"], cfg, h)
            elif kind == "mlstm":
                y, nc[f"b{i}"] = _mlstm_prefill(pp[f"b{i}"], cfg, h)
            else:
                y, nc[f"b{i}"] = _slstm_prefill(pp[f"b{i}"], cfg, h)
            x = x + y
            if cfg.cross_attention and kind == "attn":
                h = L.rmsnorm(pp[f"ln_x{i}"], x, cfg.norm_eps)
                kv = L.encoder_kv(pp[f"x{i}"], cfg, enc_out)
                x = x + L.cross_attention(pp[f"x{i}"], cfg, h, kv)
            mk = cfg.mlp_pattern[i % len(cfg.mlp_pattern)]
            if mk == "dense":
                h = L.rmsnorm(pp[f"ln_m{i}"], x, cfg.norm_eps)
                x = x + L.mlp(pp[f"m{i}"], h)
            elif mk == "moe":
                h = L.rmsnorm(pp[f"ln_m{i}"], x, cfg.norm_eps)
                y, _ = M.moe(pp[f"m{i}"], cfg, h)
                x = x + y
        return x, nc

    x, cache = jax.lax.scan(body, x, (params["layers"], cache))
    logits = _head(params, cfg, x[:, -1:])[:, 0]
    return logits, cache, enc_out


def _mamba_prefill(p, cfg, x):
    """Sequence forward that also returns the final recurrent state by
    replaying the last token through the recurrence (cheap, exact)."""
    y = S.mamba(p, cfg, x)
    # state: run the associative scan pieces once more to get h_S & window
    B, Sq, _ = x.shape
    cache = S.mamba_init_cache(cfg, B, x.dtype)
    # recompute final ssm state via a single pass over the last K tokens is
    # NOT exact for h; do the exact thing: step the recurrence over the
    # sequence with a scan (state-only, no outputs materialized).
    DI = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xi, _ = jnp.split(xz, 2, axis=-1)
    K = cfg.ssm_conv
    pad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(pad[:, k : k + Sq, :] * p["conv"][k].astype(x.dtype) for k in range(K))
    u = jax.nn.silu(conv)
    proj = jnp.einsum("bsi,ie->bse", u, p["x_proj"].astype(x.dtype)).astype(jnp.float32)
    Bm, dt = proj[..., :N], proj[..., -1:]
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["a_log"])
    uf = u.astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A[None, None])
    bx = (dt[..., None] * Bm[:, :, None, :]) * uf[..., None]

    def step(h, xs):
        at, bt = xs
        return at * h + bt, None

    h, _ = jax.lax.scan(
        step, jnp.zeros((B, DI, N), jnp.float32),
        (a.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3)),
    )
    cache = {"conv": pad[:, Sq:, :], "h": h}  # last K-1 inputs
    return y, cache


def _mlstm_prefill(p, cfg, x):
    y = X.mlstm(p, cfg, x)
    # exact final state via stepwise scan (state only)
    B, Sq, D = x.shape
    H = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, p["up"].astype(x.dtype))
    xin, _ = jnp.split(up, 2, axis=-1)
    DI = xin.shape[-1]
    hd = DI // H
    import numpy as np
    q = jnp.einsum("bse,ef->bsf", xin, p["wq"].astype(x.dtype)).reshape(B, Sq, H, hd)
    k = jnp.einsum("bse,ef->bsf", xin, p["wk"].astype(x.dtype)).reshape(B, Sq, H, hd) * (1.0 / float(np.sqrt(hd)))
    v = jnp.einsum("bse,ef->bsf", xin, p["wv"].astype(x.dtype)).reshape(B, Sq, H, hd)
    gates = (jnp.einsum("bse,eg->bsg", xin, p["wif"].astype(x.dtype)).astype(jnp.float32)
             + p["if_bias"])
    li = jnp.minimum(gates[..., :H], 10.0)
    f = jax.nn.sigmoid(gates[..., H:])

    def step(carry, xs):
        C, n = carry
        kt, vt, it, ft = xs
        C = C * ft[..., None, None] + it[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", vt.astype(jnp.float32), kt.astype(jnp.float32))
        n = n * ft[..., None] + it[..., None] * kt.astype(jnp.float32)
        return (C, n), None

    (C, n), _ = jax.lax.scan(
        step,
        (jnp.zeros((B, H, hd, hd), jnp.float32), jnp.zeros((B, H, hd), jnp.float32)),
        (k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3),
         jnp.exp(li).transpose(1, 0, 2), f.transpose(1, 0, 2)),
    )
    return y, {"C": C, "n": n}


def _slstm_prefill(p, cfg, x):
    y = X.slstm(p, cfg, x)
    B, Sq, D = x.shape
    H = cfg.n_heads
    hd = D // H
    wx = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))

    def step(state, wx_t):
        return X._slstm_cell(p, cfg, wx_t, state), None

    init = (
        jnp.zeros((B, H, hd), x.dtype),
        jnp.zeros((B, H, hd), jnp.float32),
        jnp.zeros((B, H, hd), jnp.float32),
        jnp.full((B, H, hd), -1e30, jnp.float32),
    )
    (h, c, n, m), _ = jax.lax.scan(step, init, wx.transpose(1, 0, 2))
    return y, {"h": h, "c": c, "n": n, "m": m}
