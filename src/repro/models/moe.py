"""Mixture-of-Experts with grouped capacity dispatch (GShard-style) and an
optional *sampled* routing mode driven by the paper's monotone inverse-CDF.

Tokens are partitioned into groups of ~``group_tokens`` (sharded over the DP
axes); each group dispatches into per-expert capacity buffers via one-hot
einsums, which GSPMD lowers to all-to-alls when experts are sharded (EP over
the `model` axis). Grouping bounds the dispatch tensor to
``T * group_tokens * top_k * cf`` elements instead of ``T^2 * k * cf`` — at
kimi-k2 scale (T=1M, E=384, k=8) that is ~40 GB in bf16 across the pod
instead of a physically impossible dense dispatch.

Capacity C = ceil(group_tokens * top_k * cf / E); overflow tokens drop
(standard; the aux loss keeps it rare, and decode parity tests run drop-free
with a raised cf).

`router_noise=True` routes the k-th expert stochastically ~ gate via the
monotone inverse CDF (the paper's mapping, batched per token; with QMC
uniforms the expert draw is stratified across the batch — DESIGN.md §4.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import Params, _init

GROUP_TOKENS = 2048  # target tokens per dispatch group


def init_moe(key, cfg: ModelConfig) -> Params:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.expert_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (D, E), scale=0.02),
        "wi": _init(ks[1], (E, D, F)),
        "wg": _init(ks[2], (E, D, F)),
        "wo": _init(ks[3], (E, F, D)),
    }
    if cfg.n_shared_experts:
        p["shared"] = {
            "wi": _init(ks[4], (D, F * cfg.n_shared_experts)),
            "wg": _init(ks[4], (D, F * cfg.n_shared_experts)),
            "wo": _init(ks[4], (F * cfg.n_shared_experts, D)),
        }
    return p


def _route(gates: jax.Array, k: int, noise_xi: jax.Array | None):
    """gates (..., E) softmax probs -> (..., k) expert ids + renorm weights."""
    if noise_xi is None:
        w, ids = jax.lax.top_k(gates, k)
        return ids, w / jnp.sum(w, axis=-1, keepdims=True)
    # Sampled routing: invert each token's gate CDF at k uniforms — the
    # paper's monotone mapping, batched per row.
    cdf = jnp.cumsum(gates, axis=-1)
    cdf = cdf / cdf[..., -1:]
    ids = jnp.sum(
        cdf[..., None, :] <= noise_xi[..., :, None], axis=-1
    ).astype(jnp.int32)
    ids = jnp.clip(ids, 0, gates.shape[-1] - 1)
    w = jnp.take_along_axis(gates, ids, axis=-1)
    return ids, w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)


def _pick_groups(T: int) -> int:
    """Largest divisor of T giving groups of <= GROUP_TOKENS tokens."""
    g = 1
    for cand in range(1, T + 1):
        if T % cand == 0 and T // cand <= GROUP_TOKENS:
            g = cand
            break
    return g


def moe(p: Params, cfg: ModelConfig, x: jax.Array, noise_xi=None):
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = _pick_groups(T)
    g = T // G
    xt = x.reshape(G, g, D)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)
    ids, weights = _route(gates, k, noise_xi)          # (G, g, k)

    cap = max(int(np.ceil(g * k / E * cfg.capacity_factor)), 1)
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.float32)            # (G,g,k,E)
    pos = (
        jnp.cumsum(onehot.reshape(G, g * k, E), axis=1).reshape(G, g, k, E)
        - onehot
    )
    keep = (pos < cap) * onehot
    pos = jnp.sum(pos * keep, axis=-1).astype(jnp.int32)          # (G,g,k)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)          # (G,g,k,C)

    dispatch = jnp.einsum("gtke,gtkc->gtec", keep, pos_oh).astype(x.dtype)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", weights, keep, pos_oh).astype(x.dtype)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xt)              # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xin, p["wi"].astype(x.dtype))
    hg = jnp.einsum("gecd,edf->gecf", xin, p["wg"].astype(x.dtype))
    out = jnp.einsum("gecf,efd->gecd", jax.nn.silu(hg) * h, p["wo"].astype(x.dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine, out)

    if "shared" in p:
        sp = p["shared"]
        hs = jnp.einsum("gtd,df->gtf", xt, sp["wi"].astype(x.dtype))
        gs = jnp.einsum("gtd,df->gtf", xt, sp["wg"].astype(x.dtype))
        y = y + jnp.einsum("gtf,fd->gtd", jax.nn.silu(gs) * hs, sp["wo"].astype(x.dtype))

    # Switch-style load-balance aux loss (per group, then averaged).
    me = jnp.mean(gates, axis=1)                                   # (G,E)
    ce = jnp.mean(jnp.sum(keep, axis=2), axis=1) / max(k, 1)       # (G,E)
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    return y.reshape(B, S, D), aux
