"""Core transformer layers: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Pure-function style: ``init_*`` builds param pytrees, ``apply`` functions are
stateless. Decode variants operate on a KV cache slice-in-place. Everything
is einsum-based so GSPMD can shard heads/ff/vocab from the PartitionSpec
rules in ``repro.dist.sharding``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = dict[str, Any]


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------- norm


def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * p["scale"]).astype(dt)


# ---------------------------------------------------------------------- rope


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (B, S, H, hd), positions (B, S) -> rotated x."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "wq": _init(ks[0], (D, H, hd)),
        "wk": _init(ks[1], (D, KV, hd)),
        "wv": _init(ks[2], (D, KV, hd)),
        "wo": _init(ks[3], (H, hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _qkv(p: Params, cfg: ModelConfig, x: jax.Array, positions, rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, cfg: ModelConfig, causal: bool, q_off: int | jax.Array = 0):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd); GQA by head-group reshape."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqhgk,bthk->bhgqt", qg, k).astype(jnp.float32) / np.sqrt(hd)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_off
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqt,bthk->bqhgk", w, v)
    return out.reshape(B, Sq, H, hd)


def attention(p: Params, cfg: ModelConfig, x, positions, causal=True) -> jax.Array:
    q, k, v = _qkv(p, cfg, x, positions, rope=True)
    if cfg.attn_impl == "flash":
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(
            q, k, v, causal=causal,
            interpret=jax.default_backend() != "tpu",
        )
    else:
        out = _sdpa(q, k, v, cfg, causal)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attention_decode(p: Params, cfg: ModelConfig, x, cache: dict, pos) -> tuple:
    """One-token decode with per-row positions (continuous batching: slots
    sit at different sequence offsets). x (B,1,D); pos (B,) int32;
    cache {k,v: (B,S,KV,hd), len scalar (bookkeeping only)}."""
    q, k, v = _qkv(p, cfg, x, pos[:, None], rope=True)
    B = x.shape[0]
    rows = jnp.arange(B)
    ck = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
    S = ck.shape[1]
    H, hd = q.shape[2], q.shape[3]
    KV = ck.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    logits = jnp.einsum("bqhgk,bthk->bhgqt", qg, ck).astype(jnp.float32) / np.sqrt(hd)
    mask = jnp.arange(S)[None] <= pos[:, None]             # (B, S)
    logits = jnp.where(mask[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqt,bthk->bqhgk", w, cv).reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv, "len": cache["len"] + 1}


def init_cross_attention(key, cfg: ModelConfig) -> Params:
    return init_attention(key, cfg)


def cross_attention(p: Params, cfg: ModelConfig, x, enc_kv) -> jax.Array:
    """enc_kv: precomputed (k, v) from encoder output."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    k, v = enc_kv
    out = _sdpa(q, k.astype(x.dtype), v.astype(x.dtype), cfg, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def encoder_kv(p: Params, cfg: ModelConfig, enc_out) -> tuple:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qk_norm:
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return k, v


# ----------------------------------------------------------------------- mlp


def init_mlp(key, d: int, ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": _init(k1, (d, ff)),
        "wg": _init(k2, (d, ff)),
        "wo": _init(k3, (ff, d)),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * h, p["wo"].astype(x.dtype))
