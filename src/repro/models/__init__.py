from .config import ModelConfig, reduced
from .model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "ModelConfig",
    "reduced",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "loss_fn",
    "prefill",
]
