"""Model package with lazy exports (PEP 562).

``repro.models.config`` is import-cheap (dataclasses only), but
``repro.models.model`` pulls jax + the distribution layer. Deferring the
re-exports means ``import repro.configs`` (which only needs ``config``)
cannot be taken down by a broken heavyweight dependency — one missing
module fails exactly the tests that touch it instead of zeroing out
collection for the whole suite (see ``tests/test_imports.py``).
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "ModelConfig": ".config",
    "reduced": ".config",
    "decode_step": ".model",
    "forward": ".model",
    "init_cache": ".model",
    "init_params": ".model",
    "loss_fn": ".model",
    "prefill": ".model",
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    try:
        mod = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(importlib.import_module(mod, __name__), name)
    globals()[name] = value   # cache: subsequent lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
