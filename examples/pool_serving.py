"""Pool serving walkthrough: thousands of per-tenant categoricals, a handful
of compiled programs, one batched drain per step (repro.pool).

  PYTHONPATH=src python examples/pool_serving.py

The scenario the paper's serving north star implies but a single forest
cannot cover: every request owns its OWN small distribution (per-request
token prior, per-client mixture, per-cell density). The pool packs them
into power-of-two size-class arenas, builds admission waves with the fused
batched builder (B distributions, one launch), and resolves a mixed
``(tenant, uniform)`` batch with one ``forest_sample_batched`` launch per
touched size class. The serving hot path goes one step further: per-slot
QMC stream state lives on device and a full drain is one stream pre-pass
plus one coalesced launch per class, with zero host-side bookkeeping
(section 6).
"""
import numpy as np
import jax.numpy as jnp

from repro.core import build_forest, sample_forest
from repro.core.cdf import normalize_weights
from repro.pool import ForestPool
from repro.serve import PooledForestSampler, Request, ServeEngine

rng = np.random.default_rng(0)

# --- 1. Admit a heterogeneous tenant wave (ragged sizes, one fused build
#        per size class instead of one compiled program per distinct n).
pool = ForestPool()
sizes = rng.integers(3, 200, size=48)
tenants = [rng.random(s).astype(np.float64) ** 4 + 1e-6 for s in sizes]
handles = pool.insert_many(tenants)
st = pool.stats()
print(f"admitted {st['tenants']} tenants into {len(st['classes'])} size "
      f"classes: {sorted(st['classes'])}")

# --- 2. Every tenant's padded forest is bit-identical to its own
#        single-distribution build (the batched-build contract).
h, w = handles[7], tenants[7]
padded = np.pad(normalize_weights(w), (0, h.size_class - len(w)))
solo = build_forest(jnp.asarray(padded), pool.classes[h.size_class].m)
row = pool.forest_row(h)
assert all(
    np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(solo, row)
)
print("tenant row == standalone build, bit for bit")

# --- 3. Bulk mixed-batch sampling: draws against many tenants, one kernel
#        launch per size class; elementwise equal to per-tenant descent.
Q = 4096
qh = [handles[i] for i in rng.integers(0, len(handles), Q)]
xi = rng.random(Q).astype(np.float32)
idx = pool.sample(qh, xi)
spot = rng.integers(0, Q, 64)
for q in spot:
    want = int(np.asarray(sample_forest(
        pool.forest_row(qh[q]), jnp.asarray([xi[q]])))[0])
    assert idx[q] == min(want, qh[q].n - 1)
print(f"mixed-batch drain over {Q} draws agrees with per-tenant descent")

# --- 4. In-place re-targeting routes through kernels/forest_delta: a
#        bit-unchanged CDF skips the rebuild entirely.
pool.update_weights(handles[0], delta=np.eye(handles[0].n)[0] * 0.25)
pool.update_weights(handles[0], pool.weights(handles[0]).astype(np.float64))
cls = pool.stats()["classes"][handles[0].size_class]
print(f"updates: {cls['delta_rebuilds']} rebuilt, {cls['delta_skips']} "
      "skipped (no bits moved)")

# --- 5. Eviction recycles rows through the free list; version counters
#        invalidate stale handles instead of leaking a neighbor's tenant.
pool.evict(handles[3])
reused = pool.insert(rng.random(handles[3].n))
assert reused.row == handles[3].row and reused.version == handles[3].version + 1
try:
    pool.sample([handles[3]], [0.5])
except ValueError:
    print("evicted handle raises; slot recycled with a version bump")

# --- 6. The stream-aware one-launch drain: serving doesn't hand the pool
#        host uniforms — per-slot QMC stream state (counters +
#        Cranley-Patterson offsets) lives ON DEVICE, one jitted pre-pass
#        ranks duplicate slots and advances every counter, and each touched
#        size class resolves with a single coalesced kernel launch that
#        recomputes the stream points in-kernel. Zero host-side counter
#        bookkeeping; bit-equal to the host QmcStreams oracle.
from repro.serve.sampler import DeviceQmcStreams, QmcStreams

dev = DeviceQmcStreams(8, seed=42)   # 8 serving slots
host = QmcStreams(8, seed=42)        # the numpy oracle twin
slots = rng.integers(0, 8, 512)      # duplicates: best-of-n per slot
live = [reused if h is handles[3] else h for h in handles]  # 5 evicted [3]
qh = [live[i] for i in rng.integers(0, len(live), 512)]
got = pool.sample_streams(qh, slots, dev)            # the hot path
want = pool.sample(qh, host.next(slots))             # oracle path
assert np.array_equal(got, want)
assert np.array_equal(host.counters, np.asarray(dev.counters))
print("stream-aware drain == host-oracle drain, counters bit-equal "
      f"({len(set(slots.tolist()))} distinct slots over {len(slots)} draws)")

# --- 7. The serving engine's multi-tenant path: prior-backed requests skip
#        the model entirely — pure categorical traffic, batched drain per
#        step (params=None: no LM in the loop).
eng = ServeEngine(params=None, cfg=None, n_slots=8, max_seq=64,
                  prior_sampler=PooledForestSampler(n_slots=8,
                                                    use_pallas=False))
reqs = [
    Request(rid=i, prompt=np.zeros(1, np.int64), max_new=8,
            prior=rng.random(rng.integers(4, 60)) + 1e-3)
    for i in range(16)
]
for r in reqs:
    eng.submit(r)
eng.run(max_steps=200)
assert all(r.done and len(r.out) == 8 for r in reqs)
assert all(all(0 <= t < len(r.prior) for t in r.out) for r in reqs)
print(f"served {len(reqs)} prior-backed requests in {eng.steps} engine steps"
      f" over {eng.n_slots} slots")

# --- 8. Per-tenant sampling method: the paper's forest-vs-alias tradeoff
#        as a per-slot attribute. Stream-sensitive tenants (QMC best-of-n)
#        keep the monotone forest descent; bulk PRNG tenants take packed
#        O(1) alias tables — same pool, same free-list/version machinery,
#        one mixed drain call, one launch per touched (method, class).
from repro.core.alias import np_sample_alias_f32

mixed = ForestPool()
ws = [rng.random(rng.integers(4, 60)) + 1e-3 for _ in range(24)]
methods = ["forest" if i % 2 == 0 else "alias" for i in range(len(ws))]
mh = mixed.insert_many(ws, method=methods)
st = mixed.stats()
print(f"mixed pool: {len(st['classes'])} forest classes + "
      f"{len(st['alias_classes'])} alias classes over {st['tenants']} tenants")
xi = rng.random(len(mh)).astype(np.float32)
out = mixed.sample(mh, xi)  # ONE call drains both methods
for i, (h, x) in enumerate(zip(mh, xi)):
    if h.method == "alias":
        t = mixed.alias_row(h)
        want = int(np_sample_alias_f32(
            np.asarray(t.q), np.asarray(t.alias), np.array([x]))[0])
        assert out[i] == min(want, h.n - 1)
print("alias lanes match the O(1) table oracle; forest lanes untouched")

# Serving-side: ``method="auto"`` resolves by stream kind — a PRNG sampler
# (MC baseline, nothing to protect) admits to alias, a QMC sampler keeps
# the monotone forest path so the stratification survives.
prng_sampler = PooledForestSampler(n_slots=8, use_pallas=False,
                                   streams="prng")
qmc_sampler = PooledForestSampler(n_slots=8, use_pallas=False)
print(f"auto under prng streams -> {prng_sampler.add(ws[0]).method}; "
      f"auto under qmc streams -> {qmc_sampler.add(ws[0]).method}")
eng2 = ServeEngine(params=None, cfg=None, n_slots=8, max_seq=64,
                   prior_sampler=prng_sampler)
reqs2 = [
    Request(rid=i, prompt=np.zeros(1, np.int64), max_new=4,
            prior=rng.random(rng.integers(4, 60)) + 1e-3,
            method=["auto", "forest", "alias"][i % 3])
    for i in range(12)
]
for r in reqs2:
    eng2.submit(r)
eng2.run(max_steps=100)
assert all(r.done and len(r.out) == 4 for r in reqs2)
assert all(all(0 <= t < len(r.prior) for t in r.out) for r in reqs2)
print(f"served {len(reqs2)} mixed-method requests in {eng2.steps} steps")

# --- 9. Hardened serving: validated admission, quarantine, and
#        snapshot/restore. Malformed weight rows are rejected at the
#        boundary with a structured taxonomy (every class a ValueError);
#        a quarantine-policy pool admits the tenant on a uniform
#        placeholder and flags it instead of failing the wave; and the
#        whole serving state (arena payloads, free lists, version
#        counters, device stream counters) round-trips through
#        save_serving/load_serving for bit-identical resumed drains.
import tempfile

from repro.robust import (
    NegativeWeightError, QuarantinedError, load_serving, save_serving,
    verify_pool,
)

try:
    pool.insert(np.asarray([2.0, -1.0, 2.0]))   # positive sum, still bad
except NegativeWeightError as e:
    print(f"rejected at admission with code {e.code!r}")

qpool = ForestPool(policy="quarantine")
ok = qpool.insert(rng.random(6) + 1e-3)
sus = qpool.insert(np.asarray([1.0, np.nan, 1.0]))  # admitted, flagged
assert qpool.is_quarantined(sus) and not qpool.is_quarantined(ok)
try:
    qpool.weights(sus)
except QuarantinedError:
    pass  # the row serves a uniform placeholder, not the bad submission
qpool.update_weights(sus, np.arange(1.0, 4.0))      # clean update clears
assert not qpool.is_quarantined(sus)
print(f"quarantine: flagged on admit, cleared by a clean update "
      f"({qpool.stats()['quarantined']} still flagged)")

with tempfile.TemporaryDirectory() as ck:
    streams2 = DeviceQmcStreams(8, seed=7)
    before = qpool.sample_streams([ok, sus] * 4, np.arange(8), streams2)
    save_serving(ck, step=1, pool=qpool, streams=streams2)
    states, step = load_serving(ck)
    rpool = ForestPool.restore(states["pool"])
    from repro.serve.sampler import restore_streams
    rstreams = restore_streams(states["streams"])
    assert verify_pool(rpool) == []
    a = qpool.sample_streams([ok, sus] * 4, np.arange(8), streams2)
    b = rpool.sample_streams([ok, sus] * 4, np.arange(8), rstreams)
    assert np.array_equal(a, b)
print("snapshot/restore: resumed drains bit-identical "
      "(verify_pool clean after restore)")
