"""End-to-end serving driver (the paper's kind: sampling in the serving hot
path). Loads a small LM, runs continuous-batched decode over a stream of
requests, sampling every token through fused-CDF + guide-table inversion
with per-slot QMC streams.

  PYTHONPATH=src python examples/serve_batched.py [--requests 16] [--alias]
"""
import argparse
import dataclasses
import time

import numpy as np
import jax

import repro.configs as C
from repro.models import init_params
from repro.serve import Request, ServeEngine, TokenSampler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--mode", default="inverse_qmc",
                    choices=["inverse_qmc", "inverse_rng", "alias"])
    args = ap.parse_args()

    cfg = dataclasses.replace(
        C.get_reduced("qwen3_4b"), dtype="float32",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=1024,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    sampler = TokenSampler(mode=args.mode, n_slots=args.slots,
                           temperature=0.8, use_pallas=False)
    eng = ServeEngine(params, cfg, n_slots=args.slots, max_seq=128,
                      sampler=sampler)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=rng.integers(4, 12)),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=2000)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"mode={args.mode}: {len(reqs)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, {eng.steps} batched decode steps)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt={list(r.prompt)[:6]}... -> {r.out[:12]}...")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
