"""Fault-tolerance demo: train, crash at a chosen step, resume, verify the
resumed trajectory is bitwise identical to an uninterrupted run.

  PYTHONPATH=src python examples/failover_demo.py
"""
import dataclasses
import shutil

import jax
import numpy as np

import repro.configs as C
from repro.train import TrainConfig, Trainer

cfg = dataclasses.replace(
    C.get_reduced("granite_3_8b"), dtype="float32", n_layers=2,
    d_model=96, n_heads=4, n_kv_heads=2, head_dim=24, d_ff=192, vocab=512,
)

def tc(path):
    return TrainConfig(steps=20, global_batch=4, seq_len=32,
                       ckpt_dir=path, ckpt_every=6, log_every=5)

shutil.rmtree("checkpoints/failover_a", ignore_errors=True)
shutil.rmtree("checkpoints/failover_b", ignore_errors=True)

print("== reference run (no failure) ==")
ref = Trainer(cfg, tc("checkpoints/failover_a")).run()

print("== run with injected failure at step 13 ==")
try:
    Trainer(cfg, tc("checkpoints/failover_b"), fail_at_step=13).run()
except RuntimeError as e:
    print(f"CRASH: {e}")

print("== resume (auto-detects latest checkpoint) ==")
resumed = Trainer(cfg, tc("checkpoints/failover_b")).run()

same = all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(resumed["params"]))
)
print(f"bitwise identical to uninterrupted run: {same}")
assert same
