"""Figure-8 reproduction: sample a 2-D HDR environment map with the radix
forest (monotone, row-then-column) vs the Alias Method, on a low-discrepancy
point set. Writes PGM images of the sampled histograms + prints errors.

The forest branch runs through :class:`repro.spatial.Map2DSampler`: ONE
multi-row builder launch replaces the old per-row Python build loop, and the
whole point set resolves in one bulk ``sample_map`` drain (marginal descent
+ one batched conditional launch per size class). The alias branch gets the
same treatment via the fused batched alias build + one bulk drain. A
differential gate asserts the bulk path reproduces the per-row
row-then-column reference elementwise — with exact zero-mass-row semantics
(no ``+ 1e-18`` fudge: an empty row's marginal interval has zero width, so
no uniform can select it).

  PYTHONPATH=src python examples/density_map_sampling.py [--n 16384]
"""
import argparse
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.configs.paper_workloads import env_map_2d
from repro.core import build_alias, build_forest, np_sample_alias, quadratic_error, sample_forest
from repro.core.cdf import normalize_weights
from repro.core.lds import sobol
from repro.kernels import ops
from repro.pool import build_alias_batched, sample_alias_batched
from repro.spatial import Map2DSampler


def write_pgm(path: str, img: np.ndarray) -> None:
    a = img / max(img.max(), 1e-30)
    a = (np.sqrt(a) * 255).astype(np.uint8)  # gamma for visibility
    with open(path, "wb") as fh:
        fh.write(f"P5\n{a.shape[1]} {a.shape[0]}\n255\n".encode())
        fh.write(a.tobytes())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--h", type=int, default=96)
    ap.add_argument("--w", type=int, default=192)
    ap.add_argument("--out", default="experiments/density_map")
    args = ap.parse_args()

    h, w, n = args.h, args.w, args.n
    img = env_map_2d(h, w)
    p_flat = (img / img.sum()).ravel()
    pts = sobol(n, dims=2).astype(np.float32)
    use_pallas = ops.use_pallas_default()

    # ---- forest branch: the bulk 2-D pipeline (no per-row build loop)
    sampler = Map2DSampler(img)
    ri, ci, _, _ = sampler.sample_map(pts)
    inv_counts = np.bincount(
        sampler.flat_index(ri, ci), minlength=h * w
    ).reshape(h, w)

    # Differential gate: the old row-then-column per-row loop, minus the
    # 1e-18 epsilon (zero-mass rows are exactly unselectable now). Class
    # rows behave exactly like build_forest over the pow2-padded row, so
    # the oracle builds at the class width; the bulk path must match
    # ELEMENTWISE — same rows, same columns, hence the same histogram.
    wc = int(sampler._class_of[0])
    f_rows = build_forest(jnp.asarray(normalize_weights(img.sum(axis=1))), h)
    rr = np.asarray(sample_forest(f_rows, jnp.asarray(pts[:, 0])))
    assert np.array_equal(rr, ri), "bulk marginal diverged from reference"
    cr = np.empty(n, np.int64)
    for r in np.unique(rr):
        mask = rr == r
        wpad = np.pad(normalize_weights(img[r]), (0, wc - w))
        f_col = build_forest(jnp.asarray(wpad), wc)
        cr[mask] = np.minimum(
            np.asarray(sample_forest(f_col, jnp.asarray(pts[mask, 1]))), w - 1
        )
    assert np.array_equal(cr, ci), "bulk conditional diverged from reference"

    # ---- alias branch: fused batched build + one bulk drain (loop killed)
    a_rows = build_alias(normalize_weights(img.sum(axis=1)))
    ra = np_sample_alias(
        np.asarray(a_rows.q, np.float64), np.asarray(a_rows.alias), pts[:, 0]
    )
    cond = np.stack([normalize_weights(img[r]) for r in range(h)])
    tbl = build_alias_batched(jnp.asarray(cond), use_pallas=use_pallas)
    ca = np.asarray(sample_alias_batched(
        tbl, jnp.asarray(ra, jnp.int32), jnp.asarray(pts[:, 1]),
        use_pallas=use_pallas,
    ))
    ali_counts = np.bincount(ra * w + ca, minlength=h * w).reshape(h, w)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    write_pgm(out / "target.pgm", img)
    write_pgm(out / "inverse.pgm", inv_counts.astype(np.float64))
    write_pgm(out / "alias.pgm", ali_counts.astype(np.float64))
    e_inv = quadratic_error(inv_counts.ravel(), p_flat)
    e_ali = quadratic_error(ali_counts.ravel(), p_flat)
    print(f"n={n}: quadratic error inverse={e_inv:.3e} alias={e_ali:.3e} "
          f"(alias/inverse = {e_ali / max(e_inv, 1e-30):.2f}x)")
    print(f"forest drain: {sampler.last_drain}")
    print(f"wrote {out}/target.pgm, inverse.pgm, alias.pgm")


if __name__ == "__main__":
    main()
