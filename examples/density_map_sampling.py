"""Figure-8 reproduction: sample a 2-D HDR environment map with the radix
forest (monotone, row-then-column) vs the Alias Method, on a low-discrepancy
point set. Writes PGM images of the sampled histograms + prints errors.

  PYTHONPATH=src python examples/density_map_sampling.py [--n 16384]
"""
import argparse
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.configs.paper_workloads import env_map_2d
from repro.core import build_alias, build_forest, np_sample_alias, quadratic_error, sample_forest
from repro.core.cdf import normalize_weights
from repro.core.lds import sobol


def write_pgm(path: str, img: np.ndarray) -> None:
    a = img / max(img.max(), 1e-30)
    a = (np.sqrt(a) * 255).astype(np.uint8)  # gamma for visibility
    with open(path, "wb") as fh:
        fh.write(f"P5\n{a.shape[1]} {a.shape[0]}\n255\n".encode())
        fh.write(a.tobytes())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--h", type=int, default=96)
    ap.add_argument("--w", type=int, default=192)
    ap.add_argument("--out", default="experiments/density_map")
    args = ap.parse_args()

    h, w, n = args.h, args.w, args.n
    img = env_map_2d(h, w)
    p_flat = (img / img.sum()).ravel()
    pts = sobol(n, dims=2).astype(np.float32)

    rows_w = normalize_weights(img.sum(axis=1))
    f_rows = build_forest(jnp.asarray(rows_w), h)
    ri = np.asarray(sample_forest(f_rows, jnp.asarray(pts[:, 0])))
    ci = np.empty(n, np.int64)
    for r in np.unique(ri):
        mask = ri == r
        f_col = build_forest(jnp.asarray(normalize_weights(img[r] + 1e-18)), w)
        ci[mask] = np.asarray(sample_forest(f_col, jnp.asarray(pts[mask, 1])))
    inv_counts = np.bincount(ri * w + ci, minlength=h * w).reshape(h, w)

    a_rows = build_alias(rows_w)
    ra = np_sample_alias(np.asarray(a_rows.q, np.float64), np.asarray(a_rows.alias), pts[:, 0])
    ca = np.empty(n, np.int64)
    for r in np.unique(ra):
        mask = ra == r
        t = build_alias(normalize_weights(img[r] + 1e-18))
        ca[mask] = np_sample_alias(np.asarray(t.q, np.float64), np.asarray(t.alias), pts[mask, 1])
    ali_counts = np.bincount(ra * w + ca, minlength=h * w).reshape(h, w)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    write_pgm(out / "target.pgm", img)
    write_pgm(out / "inverse.pgm", inv_counts.astype(np.float64))
    write_pgm(out / "alias.pgm", ali_counts.astype(np.float64))
    e_inv = quadratic_error(inv_counts.ravel(), p_flat)
    e_ali = quadratic_error(ali_counts.ravel(), p_flat)
    print(f"n={n}: quadratic error inverse={e_inv:.3e} alias={e_ali:.3e} "
          f"(alias/inverse = {e_ali / max(e_inv, 1e-30):.2f}x)")
    print(f"wrote {out}/target.pgm, inverse.pgm, alias.pgm")


if __name__ == "__main__":
    main()
