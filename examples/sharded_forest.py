"""Sharded forest demo: cell-partitioned *windowed* build + the owner-routed
all-to-all bulk drain over 8 fake CPU devices, bit-identical to the
single-device path — plus occupancy rebalancing for a spiky distribution and
an in-place delta update that rebuilds only the dirty shards' windows.

  PYTHONPATH=src python examples/sharded_forest.py

The device-count flag must be set before jax initializes, so this script
sets it first thing (drop it to run everything on 1 device).
"""
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import build_forest, forest_to_numpy, sample_forest
from repro.core.cdf import normalize_weights
from repro.dist import forest as DF

n, m = 1 << 14, 1 << 14
weights = normalize_weights(np.arange(1, n + 1, dtype=np.float64) ** 20)
devices = jax.devices()
print(f"devices: {len(devices)} x {devices[0].platform}")

# --- build: single-device reference vs cell-partitioned sharded -------------
f1 = build_forest(jnp.asarray(weights), m)
sharded = DF.build_forest_sharded(jnp.asarray(weights), m)
D = sharded.n_shards
bounds = DF.cell_partition(m, D)
print(f"sharded over {D} shards, cell ranges "
      + ", ".join(f"[{bounds[i]},{bounds[i+1]})" for i in range(min(D, 4)))
      + (", ..." if D > 4 else ""))

gathered = DF.gather_forest(sharded)
a, b = forest_to_numpy(f1), forest_to_numpy(gathered)
for key in ("cdf", "table", "left", "right", "cell_first", "fallback"):
    assert np.array_equal(a[key], b[key]), key
print("build: sharded gather is BIT-IDENTICAL to single-device build_forest")
print(f"windowed: each of the {D} shards built a {sharded.capacity}-leaf "
      f"window of the {n}-leaf world "
      f"(owned leaves per shard: {np.asarray(sharded.window_count).tolist()})")

# --- sample: owner-routed bulk drain vs Algorithm 2 -------------------------
# The batch is sharded over the mesh data axis. Each shard buckets its
# ~B/D draws by owning shard (host-planned static bucket capacity), one
# all_to_all delivers every draw to its owner, the owner descends ONLY its
# owned draws over its local leaf window, and a second all_to_all routes the
# interval ids back. The drain plan shows the structural win: descent lanes
# per shard ~B/D, not the full batch every shard pays on the replicated
# masked-psum oracle (routed=False, kept as the reference).
xi = jnp.asarray(np.random.default_rng(0).random(1 << 16), jnp.float32)
plan = DF.drain_plan(sharded, xi)
print(f"drain plan: {plan['batch']} draws -> {plan['lanes_per_shard']} lanes "
      f"per shard, bucket capacity {plan['bucket_capacity']} -> each shard "
      f"descends {plan['descent_lanes']} lanes (oracle descends all "
      f"{plan['padded_batch']})")
ids_sharded = np.asarray(DF.sample_sharded(sharded, xi))
ids_oracle = np.asarray(DF.sample_sharded(sharded, xi, routed=False))
ids_single = np.asarray(sample_forest(f1, xi))
assert np.array_equal(ids_sharded, ids_single)
assert np.array_equal(ids_oracle, ids_single)
print(f"sampling: {xi.shape[0]} owner-routed draws == masked-psum oracle "
      "== single-device draws")

counts = np.bincount(ids_sharded, minlength=n)
expected = weights * len(np.asarray(xi))
chi2 = float(np.sum((counts - expected) ** 2 / np.maximum(expected, 1e-9)))
print(f"chi-square vs target weights: {chi2:.0f} (dof {n - 1})")

# --- occupancy rebalancing --------------------------------------------------
# The i^20 distribution piles nearly all its probability mass — and hence
# nearly all its CDF intervals — into the last guide cells. An equal-width
# cell partition puts almost every leaf on the last shard; occupancy
# rebalancing keeps the partition contiguous and cell-aligned but sizes the
# cell ranges by leaf count, shrinking the static window capacity every
# shard must budget for.
rebalanced = DF.build_forest_sharded(jnp.asarray(weights), m, rebalance=True)
rb = DF.gather_forest(rebalanced)
b = forest_to_numpy(rb)
for key in ("cdf", "table", "left", "right", "cell_first", "fallback"):
    assert np.array_equal(a[key], b[key]), key
rbounds = np.asarray(rebalanced.cell_bounds)
print(f"rebalance: window capacity {sharded.capacity} -> "
      f"{rebalanced.capacity}, cell ranges "
      + ", ".join(f"[{rbounds[i]},{rbounds[i+1]})" for i in range(D))
      + " — still bit-identical")
# The two partitions balance *different* loads. Guide cells are
# equal-probability strata of xi, so the equal-width partition is already
# optimal for the routed drain's owner loads (~B/D draws each) — it's the
# *build* that piles onto one shard. Occupancy rebalance flips that: build
# windows even out, but nearly all cells (hence nearly all draws) now
# belong to one shard, so its drain bucket saturates at lanes-per-shard.
rplan = DF.drain_plan(rebalanced, xi)
assert np.array_equal(np.asarray(DF.sample_sharded(rebalanced, xi)),
                      ids_single)
print(f"drain plan equal vs rebalanced partition: bucket "
      f"{plan['bucket_capacity']} -> {rplan['bucket_capacity']}, descent "
      f"lanes per shard {plan['descent_lanes']} -> {rplan['descent_lanes']} "
      f"— build balance and drain balance trade off on spiky weights")

# --- delta update -----------------------------------------------------------
# Re-target a handful of weights in place: the CDF is patched through the
# fixed SCAN_CHUNKS grid, the changed-bits mask comes from the
# kernels/forest_delta pass, and only shards whose leaf windows actually
# moved rebuild their (window-sized) trees. Integer-valued weights keep the
# scan exact, so the sparse perturbation really leaves most shards clean.
iw = np.random.default_rng(1).integers(2, 50, n).astype(np.float32)
base = DF.build_forest_sharded(jnp.asarray(iw), m)
iw2 = iw.copy()
iw2[n // 2] += 1.0
iw2[n // 2 + 1] -= 1.0   # total preserved -> one CDF entry moves
updated, stats = DF.update_forest_sharded(
    base, jnp.asarray(iw2), with_stats=True)
scratch = DF.build_forest_sharded(
    jnp.asarray(iw2), m, partition=np.asarray(base.cell_bounds),
    capacity=updated.capacity)  # hysteresis may keep the larger window
for key in updated._fields:
    assert np.array_equal(np.asarray(getattr(updated, key)),
                          np.asarray(getattr(scratch, key))), key
from repro.core.cdf import SCAN_CHUNKS  # noqa: E402
print(f"delta update: {stats['rebuilt_windows']}/{D} shard windows rebuilt "
      f"({stats['dirty_chunks']}/{SCAN_CHUNKS} scan chunks dirty) — "
      f"ShardedForest bit-identical to a from-scratch rebuild")
noop, nstats = DF.update_forest_sharded(base, jnp.asarray(iw), with_stats=True)
assert not nstats["rebuilt"]
print("delta update: no-op delta skips the tree rebuild entirely")

# --- device-count sweep -----------------------------------------------------
print("build/sample timing sweep (fake devices share one core; the row "
      "structure, not the absolute us, is the point here):")
for D in (c for c in (1, 2, 4, 8) if c <= len(devices)):
    mesh = Mesh(np.asarray(devices[:D]), ("data",))
    sf = DF.build_forest_sharded(jnp.asarray(weights), m, mesh=mesh)
    jax.block_until_ready(sf.left)           # compile + warm
    t0 = time.perf_counter()
    for _ in range(3):
        sf = DF.build_forest_sharded(jnp.asarray(weights), m, mesh=mesh)
        jax.block_until_ready(sf.left)
    t_build = (time.perf_counter() - t0) / 3
    times = {}
    for routed in (True, False):
        jax.block_until_ready(DF.sample_sharded(sf, xi, mesh=mesh,
                                                routed=routed))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(DF.sample_sharded(sf, xi, mesh=mesh,
                                                    routed=routed))
        times[routed] = (time.perf_counter() - t0) / 3
    print(f"  D={D}: build {t_build * 1e3:8.1f} ms   "
          f"sample routed {times[True] * 1e3:8.1f} ms / "
          f"oracle {times[False] * 1e3:8.1f} ms / {xi.shape[0]} draws")
