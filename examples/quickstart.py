"""Quickstart: build a radix tree forest, sample, inspect (paper Secs. 3.1-3.2).

  PYTHONPATH=src python examples/quickstart.py

One distribution, many draws is the paper's amortized workload; for the
multi-tenant twin (thousands of small per-request distributions, batched
construction + bulk mixed-batch sampling via ``repro.pool``) see
``examples/pool_serving.py``. For the 2-D walkthrough — the paper's
environment-map application served as a row marginal plus pow2-size-class
conditional stacks (``repro.spatial.Map2DSampler``, one multi-row build
launch per class, one bulk ``sample_map`` drain) — see
``examples/density_map_sampling.py``.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    build_forest,
    depth_stats,
    normalize_weights,
    np_sample_forest_counting,
    sample_binary,
    sample_forest,
    table1_row,
    validate_forest,
)

# A high-dynamic-range discrete distribution (the paper's sweet spot).
n, m = 1024, 1024
weights = normalize_weights(np.arange(1, n + 1, dtype=np.float64) ** 20)

forest = build_forest(jnp.asarray(weights), m)
validate_forest(forest)
print(f"forest over n={n} intervals, m={m} guide cells")
print(f"  tagged single-interval cells: {int((np.asarray(forest.table) < 0).sum())}/{m}")
print(f"  max tree depth: {depth_stats(forest)['max_depth']}")
print(f"  degenerate cells flagged for balanced fallback: "
      f"{int(np.asarray(forest.fallback).sum())}")

# Sample: monotone inverse CDF via guide table + radix tree (Algorithm 2).
xi = np.random.default_rng(0).random(1 << 16).astype(np.float32)
idx = np.asarray(sample_forest(forest, jnp.asarray(xi)))
oracle = np.asarray(sample_binary(forest.cdf, jnp.asarray(xi)))
assert np.array_equal(idx, oracle), "forest must invert the CDF exactly"
print("sampling: forest == searchsorted oracle on 65536 draws")

# The cost the paper optimizes: memory loads, esp. the warp-synchronized max.
_, loads = np_sample_forest_counting(forest, xi)
print("load counts:", table1_row(loads))

# Distribution check.
counts = np.bincount(idx, minlength=n)
top = np.argsort(weights)[-3:][::-1]
for i in top:
    print(f"  p[{i}]={weights[i]:.4f}  observed={counts[i] / len(xi):.4f}")
