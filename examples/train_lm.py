"""Training driver: ~100M-parameter LM on the synthetic mixture pipeline
(radix-forest corpus sampling), with checkpointing and auto-resume.

Default config is a 113M-param dense decoder. On this 1-core CPU a full
"few hundred steps" run takes a while; --preset tiny gives a fast sanity
run. Kill it mid-run and re-invoke: it resumes from the last checkpoint
and (by the fault-tolerance contract) lands on the identical trajectory.

  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 60
  PYTHONPATH=src python examples/train_lm.py --steps 300   # ~100M params
"""
import argparse
import dataclasses

import repro.configs as C
from repro.models.config import ModelConfig
from repro.train import AdamWConfig, TrainConfig, Trainer


def preset_100m() -> ModelConfig:
    return dataclasses.replace(
        C.get("qwen1_5_0_5b"),
        name="dense-113m",
        n_layers=10, d_model=640, n_heads=10, n_kv_heads=10, head_dim=64,
        d_ff=1728, vocab=50304, tie_embeddings=False, dtype="float32",
    )


def preset_tiny() -> ModelConfig:
    return dataclasses.replace(
        preset_100m(), name="dense-3m", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=256, vocab=2048,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=["100m", "tiny"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="checkpoints/train_lm")
    args = ap.parse_args()

    cfg = preset_100m() if args.preset == "100m" else preset_tiny()
    total, _ = cfg.param_count()
    print(f"model {cfg.name}: {total / 1e6:.1f}M params")
    tc = TrainConfig(
        steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=f"{args.ckpt}_{args.preset}", ckpt_every=25, log_every=5,
        mixture_weights=(0.5, 0.25, 0.125, 0.125),
    )
    oc = AdamWConfig(lr=6e-4, total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 2))
    out = Trainer(cfg, tc, oc=oc).run()
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
